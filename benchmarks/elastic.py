"""Elastic re-meshing churn: BigCrush with the pool width bouncing
8 -> 4 -> 8 mid-run (the paper's opportunistic condor pool — machines
vacate when their owner returns and rejoin later) vs the same battery on
a fixed 8-wide pool.

Two numbers matter: the wall-clock cost of churn (the 4-wide stretch
runs at half throughput and the resize recompiles one extra round
program), and the accuracy criterion — the stitched p-values of the
churned run must be BITWISE those of the fixed-width run, because job
identity (generator sub-streams) never depends on pool width.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time


def _cli_run(json_path, *extra):
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    t0 = time.time()
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.battery", "--battery",
         "bigcrush", "--gen", "splitmix64", "--scale", "0.0625",
         "--workers", "8", "--json", json_path, *extra],
        env=env, capture_output=True, text=True)
    dt = time.time() - t0
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
    with open(json_path) as f:
        return dt, json.load(f)


def run(rows):
    with tempfile.TemporaryDirectory() as td:
        t_fixed, rep_fixed = _cli_run(os.path.join(td, "fixed.json"))
        t_churn, rep_churn = _cli_run(os.path.join(td, "churn.json"),
                                      "--resize-at", "3:4,6:8")
    pv = lambda rep: [(t["index"], t["stat"], t["p"])
                      for t in rep["runs"]["splitmix64"]["tests"]]
    bitwise = pv(rep_fixed) == pv(rep_churn)
    rows.append(("elastic_bigcrush_fixed_8w", t_fixed * 1e6,
                 f"rounds={rep_fixed['rounds_run']}"))
    rows.append(("elastic_bigcrush_churn_8_4_8", t_churn * 1e6,
                 f"rounds={rep_churn['rounds_run']}_"
                 f"resizes={len(rep_churn['resizes'])}_"
                 f"churn_cost={t_churn / max(t_fixed, 1e-9):.2f}x_"
                 f"bitwise_equal={bitwise}"))
    assert bitwise, "churned run must stitch bitwise-identical p-values"

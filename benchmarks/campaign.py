"""Campaign screening vs the naive per-cell loop (DESIGN.md §8).

Three numbers matter:

  * DISPATCHES — a campaign wave carries all surviving cells on the
    vmapped cell axis, so a G x S grid pays one plan's round dispatches
    where the naive loop pays one plan PER CELL (the paper's batch-model
    metric: ceil(K/W) batches, here further divided by the grid size);
  * KNOCKOUT — cells failed by the cheap phases (seam check, screening
    wave) never reach the expensive confirmation wave;
  * wall clock — honest caveat: on a single CPU device the vmapped cell
    axis SERIALIZES, so batched wall ~= per-cell wall (plus the seam
    phase the naive loop doesn't run); the dispatch-count ratio is what
    turns into wall clock on hardware with a real parallel axis, which
    is why ``wave_makespan``'s model ratio is reported alongside.

Both strategies get a fresh PoolSession and full compile-cache sharing
(one trace serves every cell either way) — the measured gap isolates
dispatch batching and knockout, not re-tracing.
"""
from __future__ import annotations

import time

GENS = ("splitmix64", "threefry", "pcg32", "randu")
N_STREAMS = 2
SCALE = 0.0625


def run(rows):
    from repro.core import Campaign, CampaignSpec, PoolSession, RunSpec
    from repro.core.scheduler import wave_makespan

    # batched: one campaign over the grid — seam check, screening wave,
    # confirmation wave (randu's cells are knocked out before the last)
    session = PoolSession()
    spec = CampaignSpec("smallcrush", GENS, n_streams=N_STREAMS, seed=5,
                        waves=(SCALE, SCALE))
    t0 = time.time()
    res = Campaign(session, spec).run()
    t_campaign = time.time() - t0
    n_cells = spec.n_cells

    # naive: one single-generator submit per cell per wave (same
    # session-level compile sharing, same sub-stream offsets)
    from repro.core.campaign import default_span
    span = default_span(spec)
    naive = PoolSession()
    t0 = time.time()
    percell_rounds = 0
    for _wave in range(2):
        for gen in GENS:
            for s in range(N_STREAMS):
                r = naive.submit(RunSpec("smallcrush", gen, 5, scale=SCALE,
                                         offsets=(s * span,))).result()
                percell_rounds += r.rounds_run
    t_percell = time.time() - t0

    from repro.core.battery import build_battery
    costs = [e.cost for e in build_battery("smallcrush", SCALE)]
    est_batched, est_percell = wave_makespan(costs, session.n_workers,
                                             n_cells)
    rows.append(("campaign_batched_4x2x2waves", t_campaign * 1e6,
                 f"dispatches={res.rounds_run}_"
                 f"phases={len(res.phase_names)}_"
                 f"traces={session.total_traces}_"
                 f"knockouts={len(res.knockouts)}"))
    rows.append(("campaign_percell_4x2x2waves", t_percell * 1e6,
                 f"dispatches={percell_rounds}_"
                 f"dispatch_ratio={percell_rounds / max(res.rounds_run, 1):.1f}x_"
                 f"wall_ratio={t_percell / max(t_campaign, 1e-9):.2f}x_"
                 f"model={est_percell / max(est_batched, 1e-9):.0f}x"))
    assert len(res.knockouts) >= N_STREAMS      # randu cells never survive
    assert session.total_traces <= len(res.phase_names)
    assert res.rounds_run < percell_rounds      # batching reduces dispatches

"""Hot-path benchmark (ISSUE 4) — the repo's perf trajectory starts here.

Measures the three legs of the Pallas-backed battery hot path and writes
``BENCH_4.json`` (the CI ``bench-hotpath`` job uploads it as an artifact):

  kernels     per-family µs, reference vs accelerated (interpret mode on
              CPU — correctness-level numbers; real-TPU perf is
              structural)
  blocks      generated-words/read-words ratio per battery, bucketed vs
              the old battery-wide-max blocks (acceptance: smallcrush
              bucketed <= 1.25)
  generators  jump-ahead vs scan block timing for the former lax.scan
              generators, plus a bit-exactness check
  rounds      fixed-seed smallcrush sequential pass, reference vs
              accelerated backend, with verdict-identity recorded

Also exposes ``run(rows)`` for the ``benchmarks/run.py`` CSV contract.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _t(fn, *a, reps=3):
    import jax
    jax.block_until_ready(fn(*a))
    t0 = time.time()
    for _ in range(reps):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6


def collect() -> dict:
    import jax
    import numpy as np

    from repro.core import pool
    from repro.core.battery import build_battery
    from repro.core.pool import run_sequential
    from repro.rng import generators as G
    from repro.stats import backends as B

    report = {"jax_backend": jax.default_backend()}

    # -- per-kernel µs: reference vs accelerated (interpret) ---------------
    cases = {
        "gap": dict(n=16384), "poker": dict(n=4096),
        "weight": dict(n=16384), "serial2d": dict(n=8192, d=32),
        "collision": dict(n=8192, kbits=14), "rank": dict(n_mats=512),
    }
    with G.x64():
        bits = G.splitmix64_block(1, 1, 262144)
    kernels = {}
    for fam, kw in cases.items():
        ref = jax.jit(lambda b, f=B.get_kernel(fam, "reference"),
                      k=kw: f(b, **k))
        acc = jax.jit(lambda b, f=B.get_kernel(fam, "accelerated"),
                      k=kw: f(b, **k))
        kernels[fam] = {"params": kw,
                        "reference_us": round(_t(ref, bits), 1),
                        "accelerated_us": round(_t(acc, bits), 1)}
    report["kernels"] = kernels

    # -- bucketed bit blocks: generated/read ratio -------------------------
    blocks = {}
    for battery in ("smallcrush", "crush", "bigcrush"):
        entries = build_battery(battery, 1.0)
        read = pool.read_words(entries)
        blocks[battery] = {
            "read_words": read,
            "generated_words_bucketed": pool.generated_words(entries),
            "bucketed": round(pool.block_ratio(entries), 4),
            # pre-bucketing hot path: every slot generated max_words
            "batterywide_max": round(
                len(entries) * max(e.n_words for e in entries) / read, 4),
        }
    report["block_ratio"] = blocks

    # -- jump-ahead generators vs their scan twins -------------------------
    from repro.common.compat import under_x64

    gens = {"bitexact": {}, "us": {}}
    n = 65536
    for name, scan in G.SCAN_REFERENCE.items():
        jump = G.GENERATORS[name]
        # seed is a RUNTIME argument — with everything static XLA
        # constant-folds the whole block and the timing is fiction
        jj = under_x64(jax.jit(lambda seed, fn=jump: fn(seed, 1, n)))
        ss = under_x64(jax.jit(lambda seed, fn=scan: fn(seed, 1, n)))
        gens["bitexact"][name] = bool(
            (np.asarray(jj(3)) == np.asarray(ss(3))).all())
        gens["us"][name] = {"jump": round(_t(jj, 3), 1),
                            "scan": round(_t(ss, 3), 1)}
    report["generators"] = gens

    # -- smallcrush round time, reference vs accelerated -------------------
    rounds = {}
    suspects = {}
    pvals = {}
    for backend in ("reference", "accelerated"):
        entries = build_battery("smallcrush", 0.125, backend=backend)
        stats, ps = run_sequential(entries, 3, G.GEN_IDS["pcg32"])
        t0 = time.time()
        stats, ps = run_sequential(entries, 3, G.GEN_IDS["pcg32"])
        jax.block_until_ready(ps)
        rounds[backend] = round((time.time() - t0) * 1e6, 1)
        pvals[backend] = np.asarray(ps)
        mask = (pvals[backend] < 1e-4) | (pvals[backend] > 1 - 1e-4)
        suspects[backend] = int(mask.sum())
    report["smallcrush_round_us"] = rounds
    report["smallcrush_suspects"] = suspects
    # PER-TEST agreement, not suspect-count coincidence: the backends
    # must produce the same p-value for every test
    report["verdict_identical"] = bool(np.allclose(
        pvals["reference"], pvals["accelerated"], rtol=1e-5, atol=1e-7))
    return report


def run(rows) -> None:
    """benchmarks/run.py CSV contract: name,us_per_call,derived."""
    rep = collect()
    rows.append(("hotpath_block_ratio_smallcrush", 0.0,
                 f"bucketed={rep['block_ratio']['smallcrush']['bucketed']}"
                 f"_was={rep['block_ratio']['smallcrush']['batterywide_max']}"))
    for fam, d in rep["kernels"].items():
        rows.append((f"hotpath_{fam}_ref", d["reference_us"], ""))
        rows.append((f"hotpath_{fam}_accel", d["accelerated_us"],
                     "interpret"))
    for gen, d in rep["generators"]["us"].items():
        rows.append((f"hotpath_gen_{gen}_jump", d["jump"],
                     f"bitexact={rep['generators']['bitexact'][gen]}"))
        rows.append((f"hotpath_gen_{gen}_scan", d["scan"], ""))
    for backend, us in rep["smallcrush_round_us"].items():
        rows.append((f"hotpath_smallcrush_{backend}", us,
                     f"suspects={rep['smallcrush_suspects'][backend]}"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", dest="json_path", default="BENCH_4.json")
    args = ap.parse_args()
    rep = collect()
    os.makedirs(os.path.dirname(args.json_path) or ".", exist_ok=True)
    with open(args.json_path, "w") as f:
        json.dump(rep, f, indent=2)
    print(f"hotpath report -> {args.json_path}")
    ratio = rep["block_ratio"]["smallcrush"]["bucketed"]
    print(f"smallcrush generated/read: {ratio} "
          f"(was {rep['block_ratio']['smallcrush']['batterywide_max']})")
    assert ratio <= 1.25, f"bucketed ratio {ratio} > 1.25"
    assert all(rep["generators"]["bitexact"].values()), \
        f"jump != scan: {rep['generators']['bitexact']}"
    assert rep["verdict_identical"], rep["smallcrush_suspects"]


if __name__ == "__main__":
    main()

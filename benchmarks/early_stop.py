"""Adaptive early stopping: rounds-executed and wall-clock, bad vs good
generators, at smallcrush and crush scales.

Ryabko's observation (arXiv:2001.11838) applied to the paper's pool:
ordering cheap, historically-discriminating tests first and stopping at
the first definitive verdict means a bad generator costs a handful of
rounds instead of a whole battery. Rows report rounds-to-verdict for the
adaptive early-stopping run vs the rounds a full battery executes, plus
the wall-clock of each. A final row sweeps EVERY registered generator at
crush scale (one multi-generator fan-out dispatch per round, failed
generators dropping out of the vmapped axis) and checks the early-stopped
verdict agrees with the full-battery verdict for each.
"""
from __future__ import annotations

import time


def _one(session, RunSpec, battery, scale, gen, stop):
    spec = RunSpec(battery, gen, 9, scale=scale, policy="adaptive",
                   stop_on_verdict=stop)
    t0 = time.time()
    res = session.submit(spec).result()
    return res, time.time() - t0


def run(rows):
    from repro.core.api import PoolSession, RunSpec
    from repro.rng.generators import GENERATORS

    session = PoolSession()
    for battery, scale in (("smallcrush", 0.125), ("crush", 0.0625)):
        for gen in ("randu", "minstd", "splitmix64"):
            full, t_full = _one(session, RunSpec, battery, scale, gen, False)
            earl, t_earl = _one(session, RunSpec, battery, scale, gen, True)
            assert earl.verdict.decision == full.verdict.decision, \
                (gen, earl.verdict, full.verdict)
            if gen in ("randu", "minstd"):
                assert earl.verdict.decision == "FAIL", earl.verdict
                assert earl.rounds_run <= full.rounds_run // 2, \
                    (gen, earl.rounds_run, full.rounds_run)
            rows.append((
                f"early_stop_{battery}_{gen}", t_earl * 1e6,
                f"rounds={earl.rounds_run}/{full.rounds_run}_"
                f"verdict={earl.verdict.decision}_"
                f"full_wall={t_full:.2f}s"))

    # every generator, one fan-out: early-stopped == full-battery verdict
    gens = tuple(GENERATORS)
    full, t_full = _one(session, RunSpec, "crush", 0.0625, gens, False)
    earl, t_earl = _one(session, RunSpec, "crush", 0.0625, gens, True)
    match = sum(earl.verdicts[g].decision == full.verdicts[g].decision
                for g in gens)
    assert match == len(gens), {
        g: (earl.verdicts[g].decision, full.verdicts[g].decision)
        for g in gens}
    fails = sorted(g for g in gens if earl.verdicts[g].decision == "FAIL")
    rows.append((
        "early_stop_crush_all_gens_fanout", t_earl * 1e6,
        f"verdict_match={match}/{len(gens)}_fails={'+'.join(fails)}_"
        f"full_wall={t_full:.2f}s"))

"""Kernel-vs-oracle timing (interpret mode on CPU — correctness-level
numbers; real-TPU perf is structural, see BlockSpecs + EXPERIMENTS.md)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _t(fn, *a):
    fn(*a)
    t0 = time.time()
    for _ in range(3):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.time() - t0) / 3 * 1e6


def run(rows):
    from repro.kernels.gf2_rank.ops import rank32
    from repro.kernels.gf2_rank.ref import gf2_rank_ref
    from repro.kernels.histogram.ops import bincount
    from repro.kernels.histogram.ref import histogram_ref
    from repro.kernels.flash_attention.ops import mha
    from repro.kernels.flash_attention.ref import attention_ref

    key = jax.random.PRNGKey(0)
    mats = jax.random.bits(key, (1024, 32), jnp.uint32)
    # interpret=True pinned: the ops default is now "auto" (compiled on a
    # real TPU), and these rows are explicitly interpreter timings
    rows.append(("kernel_gf2_rank_interp",
                 _t(lambda m: rank32(m, interpret=True), mats), "1024_mats"))
    rows.append(("kernel_gf2_rank_ref", _t(jax.jit(gf2_rank_ref), mats), ""))

    idx = jax.random.randint(key, (65536,), 0, 64)
    rows.append(("kernel_histogram_interp",
                 _t(lambda x: bincount(x, 64, interpret=True), idx),
                 "64_bins_65536"))
    rows.append(("kernel_histogram_ref",
                 _t(jax.jit(lambda x: histogram_ref(x, 64)), idx), ""))

    q = jax.random.normal(key, (1, 512, 4, 64))
    rows.append(("kernel_flash_attn_interp",
                 _t(lambda a: mha(a, a, a, scale=0.125, interpret=True), q),
                 "s512_h4_d64"))
    qf = q.transpose(0, 2, 1, 3).reshape(4, 512, 64)
    rows.append(("kernel_flash_attn_ref",
                 _t(jax.jit(lambda a: attention_ref(a, a, a, scale=0.125)),
                    qf), ""))

"""Per-arch reduced train-step wall time on CPU (smoke-scale; the full
configs' performance story is the dry-run roofline in EXPERIMENTS.md)."""
from __future__ import annotations

import time

import jax


def run(rows):
    from repro.configs import ARCH_IDS, get_reduced
    from repro.data.synthetic import batch_at
    from repro.models import lm
    from repro.train.optim import OptConfig, init_opt_state
    from repro.train.step import make_train_step

    for arch in ARCH_IDS:
        cfg = get_reduced(arch)
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        state = {"params": params, "opt": init_opt_state(params)}
        step_fn = jax.jit(make_train_step(cfg, OptConfig()),
                          donate_argnames=("state",))
        frames = ((2, cfg.encoder_seq, cfg.d_model)
                  if cfg.family == "audio" else None)
        batch = batch_at(0, 0, 2, 64, cfg.vocab_size, frames)
        state, m = step_fn(state, batch)          # compile
        t0 = time.time()
        for i in (1, 2, 3):
            batch = batch_at(0, i, 2, 64, cfg.vocab_size, frames)
            state, m = step_fn(state, batch)
        jax.block_until_ready(m)
        rows.append((f"lm_step_{arch}", (time.time() - t0) / 3 * 1e6,
                     f"loss={float(m['loss']):.3f}"))

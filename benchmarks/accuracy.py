"""Paper §11 accuracy table: GNU-diff analogue — pool results must equal
the single-worker individual-test run exactly; sequential-reuse mode
differs (different stream positions) but stays valid (no suspects)."""
from __future__ import annotations

import numpy as np


def run(rows):
    from repro.core.battery import build_battery
    from repro.core.pool import make_batch_runner, run_sequential
    from repro.core.scheduler import make_plan
    from repro.core import stitch
    from repro.launch.mesh import make_pool_mesh
    from repro.rng.generators import GEN_IDS

    entries = build_battery("smallcrush", 0.125)
    mesh = make_pool_mesh()
    stats_seq, ps_seq = run_sequential(entries, 3, GEN_IDS["pcg32"])
    runner = make_batch_runner(entries, mesh)
    plan = make_plan([e.cost for e in entries], 1, "lpt")
    st, ps = runner(np.asarray(plan.assignment), np.int32(3),
                    np.int32(GEN_IDS["pcg32"]))
    res = stitch.fold(plan.assignment, np.asarray(st), np.asarray(ps))
    equal = sum(np.isclose(res[i][1], float(ps_seq[i]), rtol=1e-6)
                for i in range(len(entries)))
    rows.append(("accuracy_pool_vs_individual", 0.0,
                 f"identical={equal}/{len(entries)}"))

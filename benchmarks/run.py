"""Benchmark harness — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV (brief contract)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (accuracy, batch_model, battery_times,
                            campaign, early_stop, elastic, hotpath,
                            kernel_bench, lm_step, submit_overhead)
    rows = []
    for mod in (batch_model, submit_overhead, accuracy, kernel_bench,
                hotpath, battery_times, early_stop, elastic, campaign,
                lm_step):
        try:
            mod.run(rows)
        except Exception:                       # noqa: BLE001
            traceback.print_exc()
            rows.append((f"{mod.__name__}_FAILED", -1.0, "see_stderr"))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    sys.exit(0)


if __name__ == "__main__":
    main()

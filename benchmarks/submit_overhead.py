"""Paper §11 'user CPU time' charts: the submit machine is busy only for
plan construction + stitching (~ms), not the battery runtime (paper: 0.02 s
to 0.39 s vs hours pinned at 100%)."""
from __future__ import annotations

import time

import numpy as np


def run(rows):
    from repro.core import stitch
    from repro.core.battery import build_battery
    from repro.core.scheduler import make_plan

    entries = build_battery("bigcrush", 1.0)
    t0 = time.time()
    plan = make_plan([e.cost for e in entries], 40, "lpt")
    t_plan = time.time() - t0
    stats = np.random.rand(*plan.assignment.shape)
    ps = np.random.rand(*plan.assignment.shape)
    t0 = time.time()
    res = stitch.fold(plan.assignment, stats, ps)
    rep = stitch.report(entries, res, "splitmix64", 1)
    t_stitch = time.time() - t0
    rows.append(("submit_overhead_plan", t_plan * 1e6, "host_side"))
    rows.append(("submit_overhead_stitch", t_stitch * 1e6,
                 f"report_lines={len(rep.splitlines())}"))

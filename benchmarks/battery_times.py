"""Paper §11 main result: battery wall-time, sequential vs pool — plus the
session API's compile-cache win.

Paper numbers (for reference): BigCrush stock ~12 h -> parallel ~4 h ->
HTCondor pool ~10.7 min (644 s) on 40 cores. Here: CPU-scaled batteries,
sequential (1 worker, stock-TestU01 model) vs an 8-worker forced-device
pool in a subprocess (the Condor model). Speedup structure, not absolute
times, is the reproduction target.

The session rows measure what the PoolSession compile cache buys: the
first submit pays trace+compile, the second submit (same battery/scale/
workers, DIFFERENT generator) reuses the jitted round program — generator
and seed are runtime arguments.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time


def _pool_run(battery, scale, workers):
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS=f"--xla_force_host_platform_device_count={workers}")
    t0 = time.time()
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.battery", "--battery", battery,
         "--gen", "splitmix64", "--scale", str(scale), "--workers",
         str(workers), "--policy", "roundrobin"],
        env=env, capture_output=True, text=True)
    dt = time.time() - t0
    assert p.returncode == 0, p.stdout[-2000:] + p.stderr[-2000:]
    return dt


def run(rows):
    from repro.core.api import PoolSession, RunSpec
    from repro.core.battery import build_battery
    from repro.core.pool import run_sequential
    from repro.rng.generators import GEN_IDS

    for battery, scale in (("smallcrush", 0.125), ("crush", 0.0625),
                           ("bigcrush", 0.0625)):
        entries = build_battery(battery, scale)
        t0 = time.time()
        run_sequential(entries, 1, GEN_IDS["splitmix64"])[1].block_until_ready()
        seq = time.time() - t0
        pool = _pool_run(battery, scale, 8)
        rows.append((f"battery_{battery}_sequential_1w", seq * 1e6,
                     f"tests={len(entries)}"))
        rows.append((f"battery_{battery}_pool_8w", pool * 1e6,
                     f"speedup_structure={seq / max(pool, 1e-9):.2f}x"
                     "(incl_process_startup)"))

    # compile-cache: second submit with a new generator must not re-trace
    session = PoolSession()
    t0 = time.time()
    session.submit(RunSpec("smallcrush", "splitmix64", 1,
                           scale=0.125)).result()
    cold = time.time() - t0
    t0 = time.time()
    session.submit(RunSpec("smallcrush", "pcg32", 1, scale=0.125)).result()
    warm = time.time() - t0
    rows.append(("battery_session_first_submit", cold * 1e6,
                 "trace+compile+run"))
    rows.append(("battery_session_cached_submit", warm * 1e6,
                 f"speedup={cold / max(warm, 1e-9):.2f}x_"
                 f"traces={session.total_traces}"))

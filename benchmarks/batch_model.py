"""Paper §11 batch model: 106 tests, cores in {40, 70, 90} -> batches
{3, 2, 2}; and the paper's wall-time prediction T ~= batches * t_batch.
Also the beyond-paper LPT scheduler's makespan on the real (skewed) battery
costs."""
from __future__ import annotations


def run(rows):
    from repro.core.battery import build_battery
    from repro.core.scheduler import make_plan

    entries = build_battery("bigcrush", 1.0)
    costs = [e.cost for e in entries]
    for w in (40, 70, 90, 256):
        rr = make_plan(costs, w, "roundrobin")
        lpt = make_plan(costs, w, "lpt")
        rows.append((f"batch_model_rr_{w}w", rr.est_makespan,
                     f"batches={rr.rounds}"))
        rows.append((f"batch_model_lpt_{w}w", lpt.est_makespan,
                     f"batches={lpt.rounds};gain={rr.est_makespan / lpt.est_makespan:.2f}x;"
                     f"ideal_frac={lpt.est_ideal / lpt.est_makespan:.2f}"))

"""Quickstart: test generators with the battery (the paper's one-command
flow) via the session API, then peek at the substrate (scheduler, kernels,
models).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.api import PoolSession, RunSpec
from repro.core.scheduler import make_plan

# 1. one declarative spec, one dispatch per round: a good and a known-bad
# generator assessed TOGETHER (the pool vmaps the job over the gen axis)
session = PoolSession()
spec = RunSpec("smallcrush", generators=("splitmix64", "randu"), seeds=(42,),
               scale=0.125)
res = session.submit(spec).result()
for gen, run in res.runs.items():
    verdict = "FAIL" if run.n_suspect else "pass"
    print(f"{gen:12s}: {verdict}  ({run.wall_s:.1f}s, "
          f"{run.rounds_run} rounds)")
print(f"(one submit, {res.rounds_run} device dispatches, "
      f"{session.total_traces} trace)")
print()

# 2. resubmitting against the same (battery, scale, workers) with the same
# generator-count shape reuses the compiled round program — generator and
# seed are runtime arguments (a different G would trace a new fan-out shape)
res2 = session.submit(RunSpec("smallcrush", ("pcg32", "threefry"), 7,
                              scale=0.125)).result()
for gen, run in res2.runs.items():
    print(f"{gen} via cache: {'FAIL' if run.n_suspect else 'pass'}")
assert session.total_traces == 1, "second submit must reuse the jitted round"
print(f"(still {session.total_traces} trace after "
      f"{2 + len(res2.runs)} generator assessments)")
print()

# 3. the paper's batch model: 106 BigCrush tests on various pool widths
for w in (40, 70, 90):
    plan = make_plan([1.0] * 106, w, "roundrobin")
    print(f"{w} workers -> {plan.rounds} batches (paper §11: 40->3, 70->2, "
          f"90->2)")
print()

# 4. the Pallas kernels validate against their oracles in interpret mode
from repro.kernels.gf2_rank.ops import rank32             # noqa: E402
from repro.kernels.gf2_rank.ref import gf2_rank_ref       # noqa: E402
mats = jax.random.bits(jax.random.PRNGKey(0), (64, 32), jnp.uint32)
assert (rank32(mats) == gf2_rank_ref(mats)).all()
print("gf2_rank kernel == oracle on 64 random 32x32 GF(2) matrices")

# 5. every assigned architecture is one import away
from repro.configs import ARCH_IDS, get_config             # noqa: E402
from repro.models.lm import count_params                   # noqa: E402
for arch in ARCH_IDS:
    print(f"  {arch:24s} {count_params(get_config(arch)) / 1e9:7.2f}B params")

"""Quickstart: test a generator with the battery (the paper's one-command
flow), then peek at the substrate (scheduler, kernels, models).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.queue import run_battery
from repro.core.scheduler import make_plan
from repro.launch.mesh import make_pool_mesh

# 1. run SmallCrush on a good and a known-bad generator (paper §10-11)
mesh = make_pool_mesh()
for gen in ("splitmix64", "randu"):
    res = run_battery("smallcrush", gen, seed=42, mesh=mesh, scale=0.125)
    verdict = "FAIL" if "SUSPECT" in res.report else "pass"
    print(f"{gen:12s}: {verdict}  ({res.wall_s:.1f}s, "
          f"{res.rounds_run} rounds)")
print()

# 2. the paper's batch model: 106 BigCrush tests on various pool widths
for w in (40, 70, 90):
    plan = make_plan([1.0] * 106, w, "roundrobin")
    print(f"{w} workers -> {plan.rounds} batches (paper §11: 40->3, 70->2, "
          f"90->2)")
print()

# 3. the Pallas kernels validate against their oracles in interpret mode
from repro.kernels.gf2_rank.ops import rank32             # noqa: E402
from repro.kernels.gf2_rank.ref import gf2_rank_ref       # noqa: E402
mats = jax.random.bits(jax.random.PRNGKey(0), (64, 32), jnp.uint32)
assert (rank32(mats) == gf2_rank_ref(mats)).all()
print("gf2_rank kernel == oracle on 64 random 32x32 GF(2) matrices")

# 4. every assigned architecture is one import away
from repro.configs import ARCH_IDS, get_config             # noqa: E402
from repro.models.lm import count_params                   # noqa: E402
for arch in ARCH_IDS:
    print(f"  {arch:24s} {count_params(get_config(arch)) / 1e9:7.2f}B params")

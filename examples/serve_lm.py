"""Serving example: batched prefill + greedy decode with per-family caches
(KV / MLA latent / SSM states).

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import serve

for arch in ("qwen2-1.5b", "deepseek-v2-236b", "xlstm-1.3b", "zamba2-1.2b"):
    toks, dt = serve(arch, reduced=True, batch=4, prompt_len=32, gen_len=12)
    print(f"{arch:20s} generated {toks.shape[0]}x{toks.shape[1]} tokens "
          f"in {dt:.2f}s | sample: {toks[0][:8].tolist()}")

"""End-to-end training driver on synthetic data with checkpointing.

    PYTHONPATH=src python examples/train_lm.py                  # quick demo
    PYTHONPATH=src python examples/train_lm.py --preset 100m    # ~100M model

The 100m preset is the brief's "train a ~100M model for a few hundred
steps" driver (hours on CPU; the same loop drives the full configs on the
production mesh via repro.launch.train --full).
"""
import argparse
import dataclasses

from repro.configs import get_reduced
from repro.launch.train import train
from repro.train.optim import OptConfig

ap = argparse.ArgumentParser()
ap.add_argument("--preset", default="small", choices=["small", "100m"])
ap.add_argument("--steps", type=int, default=0)
args = ap.parse_args()

if args.preset == "small":
    steps = args.steps or 60
    state, losses = train("qwen2-1.5b", steps=steps, global_batch=8,
                          seq_len=128, ckpt_path="/tmp/train_lm.ck",
                          log_every=10)
else:
    # ~100M-param qwen2-family config
    import repro.configs.qwen2_1_5b as q
    cfg = dataclasses.replace(
        q.CONFIG, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_ff=2048, vocab_size=32000, train_accum=1)
    from repro.models.lm import count_params
    print(f"preset 100m: {count_params(cfg) / 1e6:.0f}M params")
    import repro.launch.train as T

    def patched_get(arch, reduced):
        return cfg
    steps = args.steps or 300
    # drive the same loop with the custom config
    import jax
    from repro.data.synthetic import batch_at
    from repro.models import lm
    from repro.train.optim import init_opt_state
    from repro.train.step import make_train_step
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params)}
    step_fn = jax.jit(make_train_step(cfg, OptConfig(lr=3e-4,
                                                     total_steps=steps)),
                      donate_argnames=("state",))
    for step in range(steps):
        batch = batch_at(0, step, 8, 512, cfg.vocab_size)
        state, m = step_fn(state, batch)
        if step % 10 == 0:
            print(f"step {step} loss {float(m['loss']):.4f}", flush=True)

print("training complete; final loss "
      f"{losses[-1]:.4f}" if args.preset == "small" else "done")

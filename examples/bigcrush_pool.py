"""End-to-end driver: BigCrush on an 8-worker pool with checkpoint/restart
and hold/release — the paper's full `master` flow (§9, Appendix A).

    PYTHONPATH=src python examples/bigcrush_pool.py

Forces 8 host devices (must run before jax import), runs ~half the battery,
"crashes", restarts from the checkpoint and finishes only the missing tests.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import time                                           # noqa: E402

from repro.core.battery import build_battery          # noqa: E402
from repro.core.queue import run_battery              # noqa: E402
from repro.ckpt import io as ckpt_io                  # noqa: E402
from repro.launch.mesh import make_pool_mesh          # noqa: E402

CKPT = "/tmp/bigcrush_progress.ck"
SCALE = 0.03125

if os.path.exists(CKPT):
    os.unlink(CKPT)

mesh = make_pool_mesh()
entries = build_battery("bigcrush", SCALE)
print(f"pool: {mesh.devices.size} workers | BigCrush: {len(entries)} tests "
      f"(scale {SCALE})")

# --- phase 1: run, then simulate a crash after the checkpoint exists
t0 = time.time()
res1 = run_battery("bigcrush", "pcg32", 7, mesh, scale=SCALE,
                   checkpoint_path=CKPT, progress=True)
print(f"\nfirst run: {res1.rounds_run} rounds, {res1.wall_s:.1f}s")

# --- phase 2: knock three results out of the checkpoint ("node failures"),
# restart, and watch only the missing tests re-run
import numpy as np                                     # noqa: E402
idx, st, pv = ckpt_io.load_flat(CKPT)
keep = ~np.isin(idx, [5, 50, 100])
ckpt_io.save(CKPT, [idx[keep], st[keep], pv[keep]])
res2 = run_battery("bigcrush", "pcg32", 7, mesh, scale=SCALE,
                   checkpoint_path=CKPT, progress=True)
print(f"restart re-ran {res2.rounds_run} round(s) for 3 lost tests "
      f"(vs {res1.rounds_run} originally)")
assert res2.results == res1.results, "restart must reconcile bitwise"
print("restart results identical -- deterministic streams reconciled")
print(res2.report.splitlines()[-1])

"""End-to-end driver: BigCrush on an 8-worker pool with checkpoint/restart
and hold/release — the paper's full `master` flow (§9, Appendix A), on the
session API (submit / poll / held / release / result).

    PYTHONPATH=src python examples/bigcrush_pool.py

Forces 8 host devices (must run before jax import), streams the battery
round by round, "crashes", restarts from the checkpoint and finishes only
the missing tests. The restart submit hits the session's compile cache —
no re-trace of the round program.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

from repro.core.api import (                          # noqa: E402
    Checkpoint, PoolSession, RunSpec)

CKPT = "/tmp/bigcrush_progress.ck"
SCALE = 0.03125

if os.path.exists(CKPT):
    os.unlink(CKPT)

session = PoolSession()
spec = RunSpec("bigcrush", generators=("pcg32",), seeds=(7,), scale=SCALE,
               checkpoint_path=CKPT)
print(f"pool: {session.n_workers} workers | BigCrush: {spec.n_tests} tests "
      f"(scale {SCALE})")

# --- phase 1: stream the run round by round (master polling `empty`),
# then simulate a crash after the checkpoint exists
run = session.submit(spec)
for status in run.stream():
    print(f"  round {status['rounds_run']}: {status['jobs_done']}/"
          f"{status['jobs_total']} files generated", flush=True)
if run.held():                                        # condor_release
    run.release()
res1 = run.result()
print(f"\nfirst run: {res1.rounds_run} rounds, {res1.wall_s:.1f}s "
      f"(traces: {session.total_traces})")

# --- phase 2: knock three results out of the checkpoint ("node failures"),
# restart, and watch only the missing tests re-run — on the CACHED program
Checkpoint.load(CKPT).drop([5, 50, 100]).save(CKPT)
run2 = session.submit(spec)
status = run2.status()
print(f"restart: {status['jobs_total'] - status['jobs_done']} jobs missing, "
      f"{run2.pending_rounds} round(s) planned")
res2 = run2.result()
print(f"restart re-ran {res2.rounds_run} round(s) for 3 lost tests "
      f"(vs {res1.rounds_run} originally); traces still "
      f"{session.total_traces} (compile cache hit)")
assert session.total_traces == 1, "restart must reuse the jitted program"
assert res2.results == res1.results, "restart must reconcile bitwise"
print("restart results identical -- deterministic streams reconciled")
print(res2.report.splitlines()[-1])

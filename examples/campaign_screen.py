"""Generator-fleet screening campaign (the paper's fleet of idle
machines, reimagined as a declarative generators x sub-streams grid —
DESIGN.md §8).

    PYTHONPATH=src python examples/campaign_screen.py

Screens 6 generators x 3 parallel sub-streams through smallcrush in two
waves (cheap screen, then confirmation), with the pairstream seam check
as phase 0. Watch three things:

  * every phase is ONE batched dispatch per round — 18 cells, but the
    compile count stays at the number of phases;
  * randu (and minstd) never reach the expensive wave: the cheap phases
    knock their cells out of the grid;
  * the ledger makes the whole campaign resumable — the script proves it
    by building a SECOND campaign over the same ledger and asserting it
    replays zero rounds (the ledger is deleted at the end, so each
    invocation starts fresh).
"""
import os
import tempfile

from repro.core import Campaign, CampaignSpec, PoolSession

GENS = ("splitmix64", "threefry", "pcg32", "lcg64", "randu", "minstd")

ledger = os.path.join(tempfile.gettempdir(), "campaign_screen.ck")
session = PoolSession()
spec = CampaignSpec("smallcrush", GENS, n_streams=3, seed=11,
                    waves=(0.0625, 0.25), ledger_path=ledger,
                    progress=True)
campaign = Campaign(session, spec)
print(f"grid: {len(GENS)} generators x {spec.n_streams} streams "
      f"({spec.n_cells} cells), span={campaign.span} words, "
      f"phases={[p.name for p in campaign.phases()]}")
result = campaign.run()
print()
print(result.report)
print(f"\nknocked out early: {result.knockouts}")
print(f"survivors (safe to use as a parallel fleet): "
      f"{sorted(set(g for g, _ in result.survivors))}")
print(f"compiles: {session.total_traces} "
      f"(phases={len(result.phase_names)}, cells={spec.n_cells} — "
      "batched dispatch, not per-cell)")

# resuming is free: same spec + same ledger -> zero rounds replayed
again = Campaign(PoolSession(), spec).run()
assert again.rounds_run == 0
assert again.decisions.tolist() == result.decisions.tolist()
print("resume from ledger: 0 rounds replayed")
os.remove(ledger)

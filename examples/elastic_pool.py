"""Elastic pool driver: BigCrush on a pool whose width bounces 8 -> 4 -> 8
mid-battery — the paper's opportunistic HTCondor model (machines join when
idle, vacate when their owner returns), as first-class `session.resize()`.

    PYTHONPATH=src python examples/elastic_pool.py

Three acts:
  1. fixed-width reference run (W=8),
  2. the same spec with the pool shrinking to 4 workers after round one
     and growing back to 8 two rounds later — the live run replans its
     residual rounds at each boundary and the stitched p-values come out
     BITWISE identical (job identity is width-independent),
  3. a checkpoint written at W=8 "crashes", loses three results, and
     resumes on a 4-worker pool — the v3 checkpoint keys results by job
     id, so nothing about the file cares what width wrote or reads it.
Only the 4-wide round program compiles extra; growing back to 8 reuses
the 8-wide executable from the compile cache.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

from repro.core.api import Checkpoint, PoolSession, RunSpec  # noqa: E402

CKPT = "/tmp/elastic_progress.ck"
SCALE = 0.03125

if os.path.exists(CKPT):
    os.unlink(CKPT)

spec = RunSpec("bigcrush", generators=("pcg32",), seeds=(7,), scale=SCALE)

# --- act 1: fixed-width reference
fixed = PoolSession(n_workers=8)
res_fixed = fixed.submit(spec).result()
print(f"fixed   : W=8 throughout, {res_fixed.rounds_run} rounds, "
      f"{res_fixed.wall_s:.1f}s ({fixed.total_traces} traces)")

# --- act 2: the pool loses half its machines after round 1, gets them
# back after round 3 — condor owners coming and going
elastic = PoolSession(n_workers=8)
run = elastic.submit(spec)
run.poll()
elastic.shrink(4)                                 # 8 -> 4: owners returned
run.poll()
run.poll()
elastic.grow(4)                                   # 4 -> 8: pool idle again
res_elastic = run.result()
widths = sorted(k[2] for k in elastic.trace_counts)
print(f"elastic : W=8->4->8, {res_elastic.rounds_run} rounds, "
      f"{res_elastic.wall_s:.1f}s (traced widths: {widths})")
assert res_elastic.results == res_fixed.results, \
    "resized run must stitch bitwise-identical p-values"
assert widths == [4, 8], "only the new width may recompile"
print("          stitched p-values bitwise equal to the fixed run")

# --- act 3: checkpoint at W=8, crash, lose three results, resume at W=4
ck_session = PoolSession(n_workers=8)
res1 = ck_session.submit(
    RunSpec("bigcrush", generators=("pcg32",), seeds=(7,), scale=SCALE,
            checkpoint_path=CKPT)).result()
Checkpoint.load(CKPT).drop([5, 50, 100]).save(CKPT)   # "node failures"
ck_session.resize(4)                              # restart on a half pool
run2 = ck_session.submit(
    RunSpec("bigcrush", generators=("pcg32",), seeds=(7,), scale=SCALE,
            checkpoint_path=CKPT))
status = run2.status()
print(f"resume  : W=4 picks up a W=8 checkpoint, "
      f"{status['jobs_total'] - status['jobs_done']} jobs missing, "
      f"{run2.pending_rounds} round(s) planned")
res2 = run2.result()
assert res2.results == res1.results, "resume must reconcile bitwise"
print(f"          re-ran {res2.rounds_run} round(s) for 3 lost tests; "
      "results bitwise equal across the width change")

# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""Deterministic synthetic token pipeline.

Stream is keyed by (seed, step) via threefry — restart-exact: resuming from
a step checkpoint replays the identical batch sequence with no data-loader
state to save (DESIGN.md §5 fault tolerance). A light Markov structure makes
the loss meaningfully decreasing (not pure noise).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def batch_at(seed: int, step: int, global_batch: int, seq_len: int,
             vocab: int, frames_spec=None):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2 = jax.random.split(key)
    # narrow effective vocab -> the unigram head is learnable in tens of
    # steps (loss floor ~ln(vocab/8) instead of ln(vocab))
    v_eff = max(vocab // 8, 2)
    base = jax.random.randint(k1, (global_batch, seq_len), 0, v_eff)
    # Markov-ish structure: half the positions copy (shifted) earlier tokens
    copy_mask = jax.random.bernoulli(k2, 0.5, (global_batch, seq_len))
    shifted = jnp.roll(base, 7, axis=1)
    tokens = jnp.where(copy_mask, shifted, base)
    batch = {"tokens": tokens,
             "labels": jnp.roll(tokens, -1, axis=1)}
    if frames_spec is not None:
        b, s, d = frames_spec
        batch["frames"] = jax.random.normal(k2, (b, s, d), jnp.bfloat16)
    return batch

"""repro.serve — screening-as-a-service (DESIGN.md §10).

A persistent submission daemon over ONE ``PoolSession``: many clients
submit ``RunSpec``/``CampaignSpec``s, get non-blocking ``Ticket``
handles back, and the queue coalesces compatible submissions into
shared dispatches (admission batching) while a content-addressed
result cache answers repeat submissions with zero dispatches."""
from repro.serve.cache import (CACHE_VERSION, CacheEntry, ResultCache,
                               cell_digest)
from repro.serve.queue import (CANCELLED, DONE, FAILED, QUEUED, RUNNING,
                               SubmissionQueue, Ticket, admission_key,
                               spec_cells)

__all__ = [
    "CACHE_VERSION", "CacheEntry", "ResultCache", "cell_digest",
    "SubmissionQueue", "Ticket", "admission_key", "spec_cells",
    "QUEUED", "RUNNING", "DONE", "CANCELLED", "FAILED",
]

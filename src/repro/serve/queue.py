"""Screening-as-a-service: the persistent async submission queue.

The paper's usage model is "submit from your desk, poll while the pool
works" — the user's machine is freed the moment ``condor_submit``
returns. ``SubmissionQueue`` is that model as a long-lived daemon: ONE
``PoolSession`` (one device mesh, one compile cache) serving many
concurrent clients, each of whom submits a ``RunSpec`` or
``CampaignSpec`` and gets back a ``Ticket`` with the familiar
HTCondor-shaped verbs (``poll``/``held``/``release``/``result``) that
never block the daemon loop. Three mechanisms make the repeat-heavy,
many-client screening workload cheap (DESIGN.md §10):

  admission batching   pending specs that agree on
                       (battery, scale, alpha, backend, policy,
                       stop_on_verdict) are coalesced into ONE merged
                       multi-generator spec — strangers share a round on
                       the vmapped gen_ids axis, results are demuxed
                       back per ticket (``stitch.demux_positions``). A
                       ``max_wait`` bound keeps admission fair: a lone
                       submission is admitted once it has waited that
                       long, batched or not.
  result cache         every cell (generator, seed, offset, battery,
                       scale, alpha, backend) is content-addressed
                       (``serve.cache``); a repeat submission anywhere
                       in the fleet returns its memoized verdict in
                       O(1) with ZERO dispatches.
  crash recovery       a batch checkpoints under a content-derived name
                       in ``state_dir`` (the v3 layout), and the cache
                       persists there too — a restarted daemon that
                       receives the same submissions re-forms the same
                       batch and resumes its rounds instead of
                       re-executing them; campaign tickets resume from
                       their own ledger exactly as ``Campaign`` does.

The daemon loop is cooperative (``step()`` does one unit of work:
resolve cache hits, admit due groups, advance every active batch by one
round / every campaign by one phase) and can be driven either inline
(``drain()``, or a ``Ticket.result()`` call) or from the background
thread ``start()`` spawns — submissions are thread-safe either way.

Typical use::

    queue = SubmissionQueue(state_dir="serve-state")
    t1 = queue.submit(RunSpec("smallcrush", "splitmix64", seeds=(7,)))
    t2 = queue.submit(RunSpec("smallcrush", "pcg32", seeds=(7,)))
    queue.drain()                       # ONE shared dispatch per round
    print(t1.result().report)
    t3 = queue.submit(RunSpec("smallcrush", "splitmix64", seeds=(7,)))
    queue.drain()                       # cache hit: zero dispatches
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
import time
from typing import Dict, List, Optional, Union

from repro.core import stitch
from repro.core.api import (BatteryResult, CampaignSpec, PoolSession,
                            RunResult, RunSpec)
from repro.core.campaign import Campaign
from repro.core.faults import FaultPlan
from repro.core.policies import RetryBudgetExhausted, get_policy
from repro.serve.cache import CacheEntry, ResultCache, cell_digest
from repro.stats import backends as kernel_backends

# ticket lifecycle states (DESIGN.md §10; FAILED is §12's graceful-
# degradation terminal — budget exhaustion resolves tickets, never hangs)
QUEUED, RUNNING, DONE, CANCELLED, FAILED = (
    "queued", "running", "done", "cancelled", "failed")


@dataclasses.dataclass(frozen=True)
class _Cell:
    """One unit of cacheable work inside a spec: a source position with
    its seed and stream offset, plus its content address. ``source`` is
    the position's ``BitSource`` (the merged batch spec is rebuilt from
    these, so captured buffers ride through admission unchanged);
    ``generator`` keeps the reporting name."""
    generator: str
    seed: int
    offset: int
    digest: str
    source: object = None


def spec_cells(spec: RunSpec) -> List[_Cell]:
    """The spec's source positions as content-addressed cells (the
    digest folds in the spec-wide battery/scale/alpha and the RESOLVED
    backend, so "auto" shares slots with whatever it resolves to). A
    captured source's cell additionally folds the FILE CONTENT digest
    (``cell_digest``'s ``source_digest``): resubmitting the same capture
    hits its memoized verdict with zero dispatches, while a re-captured
    or byte-modified file is a different cell and misses. The spec's
    verdict engine folds in too (non-default only), so a cached
    Bonferroni decision can never answer an e-value submission."""
    resolved = kernel_backends.resolve(spec.backend)
    cells = []
    for g, src in enumerate(spec.sources):
        gen = spec.generators[g]
        off = int(spec.offsets[g]) if spec.offsets is not None else 0
        cells.append(_Cell(gen, int(spec.seeds[g]), off,
                           cell_digest(spec.battery, spec.scale, gen,
                                       spec.seeds[g], off, spec.alpha,
                                       resolved,
                                       src.digest() if src.captured
                                       else "",
                                       engine=spec.verdict_engine),
                           src))
    return cells


def admission_key(spec: RunSpec) -> tuple:
    """The compatibility class admission batching coalesces within:
    specs agreeing on (battery, scale, alpha, resolved backend, policy,
    stop_on_verdict, fault plan, verdict engine) can share one dispatch
    — everything else about them (generators, seeds, offsets) is a
    runtime argument of the merged run. A spec carrying an ``inject``
    plan only batches with specs carrying the SAME plan (fault
    injection is a property of the shared dispatch, so strangers must
    not inherit it silently); engines must match because the engine
    steers the merged run's early stopping and cache entries."""
    policy = get_policy(spec.policy)
    return (spec.battery, float(spec.scale), float(spec.alpha),
            kernel_backends.resolve(spec.backend), policy.name,
            policy.signature(), bool(spec.stop_on_verdict), spec.inject,
            spec.verdict_engine)


class Ticket:
    """A client's handle on one submission — the serve-layer analogue of
    ``BatteryRun``, with the same HTCondor-shaped verbs, none of which
    block the daemon: ``poll()`` advances the daemon one cooperative
    step (a no-op when a background thread is serving) and reports,
    ``held()``/``release()`` reach through to the shared batch run,
    ``result()`` waits for (or drives to) completion. ``cache_hits``
    counts the ticket's cells served from the result cache."""

    def __init__(self, queue: "SubmissionQueue", tid: str,
                 spec: Union[RunSpec, CampaignSpec], kind: str):
        self._queue = queue
        self.id = tid
        self.spec = spec
        self.kind = kind                      # "run" | "campaign"
        self.state = QUEUED
        self.submitted = time.monotonic()
        self.batch_id: Optional[int] = None
        self.cache_hits = 0
        self.failure: Optional[dict] = None         # FAILED terminal detail
        self._cached: Dict[int, CacheEntry] = {}    # position -> entry
        self._positions: Dict[int, int] = {}        # position -> batch pos
        self._campaign: Optional[Campaign] = None
        self._result = None
        self._event = threading.Event()

    # -- verbs -------------------------------------------------------------

    @property
    def done(self) -> bool:
        """True once the ticket reached a terminal state (DONE,
        CANCELLED or FAILED — a failed ticket is resolved, not stuck)."""
        return self.state in (DONE, CANCELLED, FAILED)

    def poll(self) -> dict:
        """One non-blocking look: advance the daemon a cooperative step
        (unless a background thread is already serving) and return this
        ticket's status snapshot."""
        if not self._queue.serving:
            self._queue.step()
        return self.status()

    def held(self) -> List[int]:
        """Job indices HELD in the shared batch this ticket rides on
        (job space is shared across the batch's tickets); empty while
        queued, cached or finished."""
        batch = self._queue._batch_of(self)
        return batch.handle.held() if batch else []

    def release(self) -> int:
        """condor_release on the shared batch run. Manual — it does NOT
        spend the driver's ``RetryPolicy`` budget (the api.py release
        discipline), and it releases the whole batch's HELD set: jobs
        are shared, so a release by any rider frees every rider."""
        batch = self._queue._batch_of(self)
        return batch.handle.release() if batch else 0

    def cancel(self) -> bool:
        """Withdraw the submission. A queued ticket leaves the pending
        set; a running one is marked cancelled and its demuxed results
        are discarded at batch finalize — the SHARED dispatch keeps
        running for the other riders (condor_rm removes your job, not
        the machine's whole batch). Returns True if a state changed."""
        return self._queue._cancel(self)

    def result(self, timeout: Optional[float] = None):
        """Block until the ticket completes and return its
        ``RunResult``/``BatteryResult`` (``CampaignResult`` for a
        campaign ticket). With a background daemon thread this waits;
        otherwise it drives the queue's cooperative loop. ``timeout``
        (seconds) raises ``TimeoutError`` when exceeded. A FAILED
        ticket (its batch exhausted the retry budget with jobs still
        HELD) raises ``RetryBudgetExhausted`` carrying the HELD job
        list — the structured terminal of DESIGN.md §12, never a
        hang."""
        if self._queue.serving:
            if not self._event.wait(timeout):
                raise TimeoutError(f"ticket {self.id} not done within "
                                   f"{timeout}s")
        else:
            deadline = (time.monotonic() + timeout
                        if timeout is not None else None)
            while not self.done:
                worked = self._queue.step(flush=True)
                if deadline is not None and time.monotonic() > deadline:
                    raise TimeoutError(f"ticket {self.id} not done "
                                       f"within {timeout}s")
                if not worked and not self.done:
                    raise RuntimeError(
                        f"ticket {self.id} stalled: the queue reports "
                        "no work left but the ticket is not terminal")
        if self.state == CANCELLED:
            raise RuntimeError(f"ticket {self.id} was cancelled")
        if self.state == FAILED:
            raise RetryBudgetExhausted(self.failure["held_jobs"],
                                       self.failure["retries"])
        return self._result

    def status(self) -> dict:
        """A condor_q-shaped snapshot: lifecycle state, batch id, cache
        hits, failure detail for a FAILED ticket, and — while the shared
        batch is live — its run counters."""
        out = {"ticket": self.id, "kind": self.kind, "state": self.state,
               "batch": self.batch_id, "cache_hits": self.cache_hits}
        if self.failure is not None:
            out["failure"] = dict(self.failure)
        batch = self._queue._batch_of(self)
        if batch is not None:
            run = batch.handle.status()
            out.update({"rounds_run": run["rounds_run"],
                        "pending_rounds": run["pending_rounds"],
                        "held": run["held"], "retries": run["retries"]})
        if self.kind == "campaign" and self._campaign is not None:
            out["phases_done"] = int(self._campaign.ledger.phases_done)
        return out


@dataclasses.dataclass
class _Batch:
    """One admitted coalition: the canonical (digest-sorted) cell list,
    the merged spec's live run handle, and the riding tickets."""
    id: int
    key: tuple
    cells: List[_Cell]
    tickets: List[Ticket]
    handle: object                  # BatteryRun
    digest: str


class SubmissionQueue:
    """The serve daemon: one ``PoolSession``, many clients (module
    docstring has the full architecture). Construct with an existing
    session to share its compile cache, or let it build one; give it a
    ``state_dir`` to persist the result cache and batch checkpoints
    across daemon restarts. ``max_wait`` (seconds) is the admission
    fairness bound — the longest any submission waits for companions
    before its batch is admitted as-is. ``inject`` applies one
    ``faults.FaultPlan`` to every merged batch the daemon forms —
    daemon-level chaos testing (DESIGN.md §12): the bitwise-degradation
    invariant means recovered results still populate the shared cache
    correctly."""

    def __init__(self, session: Optional[PoolSession] = None,
                 cache: Optional[ResultCache] = None,
                 state_dir: Optional[str] = None,
                 max_wait: float = 0.0,
                 inject: Optional[FaultPlan] = None):
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.session = session or PoolSession()
        self.inject = inject
        self._peak_workers = self.session.n_workers
        self.state_dir = state_dir
        if state_dir:
            os.makedirs(state_dir, exist_ok=True)
        self.cache = cache if cache is not None else ResultCache(
            os.path.join(state_dir, "cache") if state_dir else None)
        self.max_wait = float(max_wait)
        self._lock = threading.RLock()
        self._tickets: Dict[str, Ticket] = {}
        self._pending: List[Ticket] = []
        self._active: List[_Batch] = []
        self._next_ticket = 0
        self._next_batch = 0
        self.dispatch_rounds = 0        # device dispatches issued, total
        self.batches_formed = 0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- client surface ----------------------------------------------------

    def submit(self, spec: Union[RunSpec, CampaignSpec]) -> Ticket:
        """Accept one submission and return its ticket immediately.
        A ``RunSpec`` whose every cell is already in the result cache
        completes here, synchronously, with zero dispatches — the O(1)
        repeat-submission path. Everything else joins the pending set
        for admission batching. Thread-safe."""
        with self._lock:
            tid = f"t{self._next_ticket}"
            self._next_ticket += 1
            kind = "campaign" if isinstance(spec, CampaignSpec) else "run"
            ticket = Ticket(self, tid, spec, kind)
            self._tickets[tid] = ticket
            if kind == "run" and self._try_cache(ticket):
                return ticket
            self._pending.append(ticket)
            return ticket

    @property
    def serving(self) -> bool:
        """True while a background daemon thread owns the loop."""
        return self._thread is not None and self._thread.is_alive()

    @property
    def idle(self) -> bool:
        """True when nothing is pending or in flight."""
        with self._lock:
            return not self._pending and not self._active

    def step(self, flush: bool = False) -> bool:
        """One cooperative unit of daemon work: complete any pending
        tickets the cache can now serve, admit every compatibility group
        past its ``max_wait`` window (``flush=True`` admits regardless
        of the window), then advance each active batch by one round and
        each active campaign by one phase. Returns True when any work
        happened — ``False`` means the queue is idle."""
        with self._lock:
            worked = self._admit(flush)
            worked = self._advance() or worked
            return worked

    def drain(self) -> None:
        """Drive the cooperative loop until every ticket is terminal
        (the inline equivalent of letting the daemon thread catch up)."""
        while self.step(flush=True):
            pass

    def start(self, poll_s: float = 0.01) -> "SubmissionQueue":
        """Spawn the background daemon thread (serve_forever): steps the
        loop, sleeping ``poll_s`` between idle checks. Returns self."""
        if self.serving:
            return self
        self._stop.clear()

        def _loop():
            while not self._stop.is_set():
                if not self.step():
                    time.sleep(poll_s)

        self._thread = threading.Thread(target=_loop, name="repro-serve",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the daemon thread (pending work stays queued — a later
        ``start()``/``drain()`` picks it up; on-disk state survives a
        full process crash via ``state_dir``)."""
        if self._thread is not None:
            self._stop.set()
            self._thread.join()
            self._thread = None

    def stats(self) -> dict:
        """Daemon counters: tickets, batches, dispatches, cache traffic,
        the session's compile-cache trace count, and the pool health
        (``workers``/``status``: a daemon whose pool shrank below its
        peak width — quarantines, lost workers — keeps serving and
        reports ``"degraded"`` instead of dying, DESIGN.md §12)."""
        with self._lock:
            cur = self.session.n_workers
            self._peak_workers = max(self._peak_workers, cur)
            return {"tickets": len(self._tickets),
                    "pending": len(self._pending),
                    "active_batches": len(self._active),
                    "batches": self.batches_formed,
                    "dispatch_rounds": self.dispatch_rounds,
                    "cache": {"hits": self.cache.hits,
                              "misses": self.cache.misses,
                              "entries": len(self.cache)},
                    "traces": self.session.total_traces,
                    "workers": cur,
                    "status": ("degraded" if cur < self._peak_workers
                               else "ok")}

    # -- cache path --------------------------------------------------------

    def _try_cache(self, ticket: Ticket) -> bool:
        """Serve the ticket entirely from the result cache when every
        cell hits; stash partial hits on the ticket either way so the
        batch only dispatches the missing cells."""
        spec = ticket.spec
        cells = spec_cells(spec)
        for g, cell in enumerate(cells):
            if g in ticket._cached:
                continue
            entry = self.cache.get(cell.digest, spec.stop_on_verdict)
            if entry is not None:
                ticket._cached[g] = entry
        ticket.cache_hits = len(ticket._cached)
        if len(ticket._cached) == len(cells):
            self._finalize_ticket(ticket, {}, rounds_run=0, retries=0,
                                  plan_rounds=0)
            return True
        return False

    # -- admission ---------------------------------------------------------

    def _admit(self, flush: bool) -> bool:
        """Form batches from the pending set: campaign tickets activate
        individually; run tickets group by ``admission_key`` and each
        group past its window is merged into one batch."""
        now = time.monotonic()
        worked = False
        groups: Dict[tuple, List[Ticket]] = {}
        for t in list(self._pending):
            if t.kind == "campaign":
                if flush or now - t.submitted >= self.max_wait:
                    self._pending.remove(t)
                    t._campaign = Campaign(self.session, t.spec)
                    t.state = RUNNING
                    worked = True
            else:
                groups.setdefault(admission_key(t.spec), []).append(t)
        for key, tickets in groups.items():
            oldest = min(t.submitted for t in tickets)
            if not flush and now - oldest < self.max_wait:
                continue
            worked = self._admit_group(key, tickets) or worked
        return worked

    def _admit_group(self, key: tuple, tickets: List[Ticket]) -> bool:
        """Merge one compatibility group into a single batch run."""
        riders: List[Ticket] = []
        need: Dict[str, _Cell] = {}
        for t in tickets:
            self._pending.remove(t)
            if self._try_cache(t):      # cache may have filled meanwhile
                continue
            riders.append(t)
            for g, cell in enumerate(spec_cells(t.spec)):
                if g not in t._cached:
                    need[cell.digest] = cell
        if not riders:
            return True
        # canonical order: sorted by digest, so the SAME submissions on
        # a restarted daemon rebuild the SAME batch (and checkpoint name)
        cells = [need[d] for d in sorted(need)]
        pos = {c.digest: i for i, c in enumerate(cells)}
        for t in riders:
            t._positions = {g: pos[c.digest]
                            for g, c in enumerate(spec_cells(t.spec))
                            if g not in t._cached}
        digest = hashlib.sha256(
            repr((key, tuple(c.digest for c in cells))).encode()
        ).hexdigest()[:16]
        spec = self._merged_spec(key, cells, riders, digest)
        batch = _Batch(self._next_batch, key, cells, riders,
                       self.session.submit(spec), digest)
        self._next_batch += 1
        self.batches_formed += 1
        for t in riders:
            t.state = RUNNING
            t.batch_id = batch.id
        self._active.append(batch)
        return True

    def _merged_spec(self, key: tuple, cells: List[_Cell],
                     riders: List[Ticket], digest: str) -> RunSpec:
        """The coalesced RunSpec: one source position per unique cell,
        every per-cell knob a runtime argument, checkpointed under a
        content-derived name so a restarted daemon resumes it. Cells
        carry their ``BitSource`` through admission, so captured buffers
        batch alongside generator positions unchanged."""
        (battery, scale, alpha, backend, _pname, _psig, sov, inject,
         engine) = key
        offsets = (tuple(c.offset for c in cells)
                   if any(c.offset for c in cells) else None)
        ck = (os.path.join(self.state_dir, f"batch-{digest}.ck")
              if self.state_dir else None)
        # the merged retry policy keeps the first rider's robustness
        # knobs (backoff, deadline, quarantine) with the group's most
        # generous budget; the daemon-level inject plan (chaos testing)
        # takes precedence over a rider-carried one
        return RunSpec(
            battery, sources=tuple(c.source for c in cells),
            seeds=tuple(c.seed for c in cells), scale=scale,
            policy=riders[0].spec.policy,
            retry=dataclasses.replace(
                riders[0].spec.retry, max_retries=max(
                    t.spec.retry.max_retries for t in riders)),
            checkpoint_path=ck, alpha=alpha, stop_on_verdict=sov,
            verdict_engine=engine, backend=backend, offsets=offsets,
            inject=self.inject if self.inject is not None else inject)

    # -- the daemon's advance ----------------------------------------------

    def _advance(self) -> bool:
        """One round per active batch, one phase per active campaign."""
        worked = False
        for batch in list(self._active):
            worked = self._advance_batch(batch) or worked
        for t in list(self._tickets.values()):
            if t.kind == "campaign" and t.state == RUNNING:
                worked = self._advance_campaign(t) or worked
        return worked

    def _advance_batch(self, batch: _Batch) -> bool:
        """Dispatch one round of the batch (or one driver-budgeted
        release pass), finalizing it once the drive policy would stop —
        the incremental twin of ``BatteryRun.drive``. A batch that
        exhausts its retry budget with jobs still HELD is routed to
        ``_fail_batch``: every rider resolves (DONE where its own cells
        are servable, FAILED otherwise) and the daemon keeps serving —
        graceful degradation, never a hang (DESIGN.md §12)."""
        h = batch.handle
        if h.pending_rounds:
            before = h.rounds_run
            h.poll()
            self.dispatch_rounds += h.rounds_run - before
            if h.pending_rounds or not (h.done or h.cancelled):
                return True
        if not (h.done or h.cancelled) and h.held():
            if h.driver_retries < h.spec.retry.max_retries:
                h._driver_release()
                return True
            self._fail_batch(batch)
            return True
        self._finalize_batch(batch)
        return True

    def _advance_campaign(self, ticket: Ticket) -> bool:
        """One campaign phase; the ticket completes when the campaign
        does (or stalls HELD through the retry budget, mirroring
        ``Campaign.run``'s stop-with-undecided-cells contract)."""
        camp = ticket._campaign
        before = camp.rounds_run
        progressed = camp.run_next_phase()
        self.dispatch_rounds += camp.rounds_run - before
        if camp.complete or not progressed:
            ticket._result = camp.result_snapshot(
                time.monotonic() - ticket.submitted)
            self._terminate(ticket, DONE)
        return True

    # -- finalize + demux --------------------------------------------------

    def _finalize_batch(self, batch: _Batch) -> None:
        """Memoize every cell's outcome, demux per-position results back
        to the riding tickets, and retire the batch."""
        h = batch.handle
        n_total = len(self.session.entries(h.spec))
        per_res = h.results_by_position()
        for c, res in zip(batch.cells, per_res):
            entry = CacheEntry.from_results(res, n_total, h.spec.alpha,
                                            engine=h.spec.verdict_engine)
            if entry.serves(stop_on_verdict=True):   # sellable to someone
                self.cache.put(c.digest, entry)
        groups = {t.id: sorted(t._positions.values())
                  for t in batch.tickets if t.state != CANCELLED}
        sliced = stitch.demux_positions(per_res, groups)
        for t in batch.tickets:
            if t.state == CANCELLED:
                continue
            by_batch_pos = dict(zip(groups[t.id], sliced[t.id]))
            per_cell = {g: by_batch_pos[p]
                        for g, p in t._positions.items()}
            self._finalize_ticket(t, per_cell, rounds_run=h.rounds_run,
                                  retries=h.retries,
                                  plan_rounds=h.plan_rounds)
        self._active.remove(batch)

    def _fail_batch(self, batch: _Batch) -> None:
        """Resolve a retry-budget-exhausted batch without hanging or
        poisoning anything: servable cells (complete, or verdict-decided
        for ``stop_on_verdict`` clients) are still memoized — the cache
        gate is ``CacheEntry.serves``, so an undecided partial NEVER
        enters the cache — riders whose own cells are all servable
        finalize DONE with their demuxed results, and every other rider
        terminates FAILED with a structured ``failure`` payload (reason,
        HELD job list, retries spent) that ``Ticket.result()`` surfaces
        as ``RetryBudgetExhausted``."""
        h = batch.handle
        held = [int(j) for j in h.held()]
        n_total = len(self.session.entries(h.spec))
        per_res = h.results_by_position()
        entries = [CacheEntry.from_results(res, n_total, h.spec.alpha,
                                           engine=h.spec.verdict_engine)
                   for res in per_res]
        for c, entry in zip(batch.cells, entries):
            if entry.serves(stop_on_verdict=True):   # sellable to someone
                self.cache.put(c.digest, entry)
        salvaged = [t for t in batch.tickets if t.state != CANCELLED
                    and all(entries[p].serves(
                        stop_on_verdict=t.spec.stop_on_verdict)
                        for p in t._positions.values())]
        groups = {t.id: sorted(t._positions.values()) for t in salvaged}
        sliced = stitch.demux_positions(per_res, groups)
        for t in batch.tickets:
            if t.state == CANCELLED:
                continue
            if t in salvaged:
                by_batch_pos = dict(zip(groups[t.id], sliced[t.id]))
                per_cell = {g: by_batch_pos[p]
                            for g, p in t._positions.items()}
                self._finalize_ticket(t, per_cell,
                                      rounds_run=h.rounds_run,
                                      retries=h.retries,
                                      plan_rounds=h.plan_rounds)
            else:
                t.failure = {
                    "reason": (f"retry budget exhausted after "
                               f"{h.driver_retries} release pass(es)"),
                    "held_jobs": held, "retries": h.driver_retries}
                self._terminate(t, FAILED)
        self._active.remove(batch)

    def _finalize_ticket(self, ticket: Ticket,
                         dispatched: Dict[int, Dict[int, tuple]],
                         rounds_run: int, retries: int,
                         plan_rounds: int) -> None:
        """Assemble the ticket's own ``RunResult``/``BatteryResult``
        from its cached cells plus the batch's demuxed positions — the
        exact shape ``BatteryRun.result()`` would have returned for the
        ticket's spec alone."""
        spec = ticket.spec
        entries = self.session.entries(spec)
        wall = time.monotonic() - ticket.submitted
        runs: Dict[str, RunResult] = {}
        for g, gen in enumerate(spec.generators):
            combined = (ticket._cached[g].results
                        if g in ticket._cached else dispatched[g])
            verdict = stitch.verdict_for(spec.verdict_engine)(
                combined, len(entries), spec.alpha)
            rep = stitch.report(entries, combined, gen, spec.seeds[g])
            runs[gen] = RunResult(combined, rep, rounds_run, retries,
                                  wall, plan_rounds, verdict=verdict)
        if spec.n_generators == 1:
            ticket._result = runs[spec.generators[0]]
        else:
            ticket._result = BatteryResult(spec, runs, rounds_run,
                                           retries, wall)
        self._terminate(ticket, DONE)

    # -- bookkeeping -------------------------------------------------------

    def _terminate(self, ticket: Ticket, state: str) -> None:
        """Move a ticket to a terminal state and wake its waiters."""
        ticket.state = state
        ticket._event.set()

    def _batch_of(self, ticket: Ticket) -> Optional[_Batch]:
        with self._lock:
            for b in self._active:
                if ticket in b.tickets:
                    return b
        return None

    def _cancel(self, ticket: Ticket) -> bool:
        with self._lock:
            if ticket.done:
                return False
            if ticket in self._pending:
                self._pending.remove(ticket)
            self._terminate(ticket, CANCELLED)
            return True

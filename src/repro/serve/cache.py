"""Content-addressed result cache for the serve layer (DESIGN.md §10).

Every unit of screening work the fleet can ever be asked to repeat is a
CELL: one generator at one (seed, stream-offset) screened by one
(battery, scale) under one (alpha, backend). ``cell_digest`` names a
cell by the sha256 of exactly that tuple — nothing about WHO asked, WHEN
it ran, or how wide the pool was — so a repeat submission anywhere in
the fleet resolves to the same address and its verdict returns in O(1)
without a dispatch.

``CacheEntry`` persists with the same wire discipline as the v3
checkpoint and the campaign ledger (``ckpt/io`` flat leaves, a version
constant the reader actually checks, atomic writes): one file per digest
under the cache root. Entries record whether the stored results are
COMPLETE (every test of the battery has a value) — a partial entry
(an adaptive run cancelled at FAIL) still serves stop-on-verdict
resubmissions, whose contract is the decision, but never a classic
resubmission that expects the full report.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Dict, Optional

import numpy as np

from repro.ckpt import io as ckpt_io
from repro.core import stitch

CACHE_VERSION = 2

# decision codes on the wire — same convention as api.CELL_* and the
# checkpoint's verdict codes
_DECISION_CODE = {stitch.UNDECIDED: 0, stitch.PASS: 1, stitch.FAIL: 2}
_CODE_DECISION = {v: k for k, v in _DECISION_CODE.items()}


def cell_digest(battery: str, scale: float, generator: str, seed: int,
                offset: int, alpha: float, backend: str,
                source_digest: str = "",
                engine: str = "bonferroni") -> str:
    """The cell's content address: a 32-hex-char sha256 prefix over the
    full decision-relevant identity (generator, seed, offset, battery,
    scale, alpha, backend). ``backend`` must be the RESOLVED backend
    (``stats.backends.resolve``) — "auto" and the backend it resolves to
    are the same work, and both backends' verdicts are parity-asserted,
    so the caller chooses whether to pass the resolved name (shared
    slots per host class) per the serve layer's convention.

    ``source_digest`` carries the bit-supply's CONTENT identity
    (``BitSource.digest()``) when it is more than the generator name:
    for a ``CapturedSource`` it is the sha256 of the file bytes, so a
    resubmitted capture HITS the cell it already earned while a
    re-captured or byte-modified file MISSES — same path, different
    bits, different cell. Generator cells pass ``""`` (their name IS
    their content identity), which keeps every digest minted before the
    BitSource layer byte-identical.

    ``engine`` is the verdict engine the cell's decision was (or will
    be) computed under. Folded only when it is not the historical
    default ("bonferroni"), the same back-compat discipline as
    ``source_digest``: every pre-engine digest stays byte-identical,
    while an e-value submission can never be answered by a cached
    Bonferroni decision (and vice versa)."""
    key = repr((str(battery), float(scale), str(generator), int(seed),
                int(offset), float(alpha), str(backend)))
    if source_digest:
        key = repr((key, str(source_digest)))
    if engine != "bonferroni":
        key = repr((key, "engine", str(engine)))
    return hashlib.sha256(key.encode()).hexdigest()[:32]


@dataclasses.dataclass
class CacheEntry:
    """One cell's memoized outcome: the combined TEST-space results
    (test index -> (stat, p)), the decision they recompute to under the
    entry's verdict engine, the alpha it was computed under, the battery
    size and a completeness flag. ``results``/``decision`` are exactly
    what a fresh run of the same cell would produce — decisions are a
    pure function of (results, alpha, engine), which is what makes
    memoization sound.

    Wire layout (``ckpt/io`` leaves, v2)::

      [version, idx (K,) int32, stats (K,) float64, ps (K,) float64,
       decision int8, alpha float64, n_total int64, complete int8,
       engine bytes]

    v1 files (8 leaves, no engine) load as ``engine="bonferroni"`` —
    the only engine that existed when they were written.
    """
    results: Dict[int, tuple]
    decision: str
    alpha: float
    n_total: int
    complete: bool
    engine: str = "bonferroni"
    version: int = CACHE_VERSION

    @classmethod
    def from_results(cls, results: Dict[int, tuple], n_total: int,
                     alpha: float,
                     engine: str = "bonferroni") -> "CacheEntry":
        """Build an entry from a finished (or verdict-decided) cell's
        combined results; decision and completeness are derived, never
        trusted from the caller."""
        verdict = stitch.verdict_for(engine)(results, n_total, alpha)
        complete = not stitch.missing(results, n_total)
        return cls(dict(results), verdict.decision, float(alpha),
                   int(n_total), complete, str(engine))

    def verdict(self):
        """The verdict recomputed from the stored results under the
        entry's engine — bitwise the one the original run reported
        (pure function)."""
        return stitch.verdict_for(self.engine)(self.results, self.n_total,
                                               self.alpha)

    def serves(self, stop_on_verdict: bool) -> bool:
        """Can this entry satisfy a resubmission? A complete entry
        serves everyone; a partial one only serves a ``stop_on_verdict``
        client, and only when its decision is definitive."""
        if self.complete:
            return True
        return bool(stop_on_verdict
                    and self.decision != stitch.UNDECIDED)

    @classmethod
    def load(cls, path: str) -> "CacheEntry":
        """Read (and version-check) one cache file — v2 (9 leaves, with
        engine) or the historical v1 (8 leaves, Bonferroni-only)."""
        leaves = ckpt_io.load_flat(path)
        if len(leaves) == 9:                    # v2: + engine
            ver, idx, st, pv, dec, alpha, n_total, complete, eng = leaves
            if int(ver) != CACHE_VERSION:
                raise ValueError(
                    f"cache entry {path} declares version {int(ver)}; "
                    f"this build reads v{CACHE_VERSION}")
            engine = (bytes(eng.reshape(-1)[0]).decode()
                      if eng.size else "bonferroni")
            version = CACHE_VERSION
        elif len(leaves) == 8:                  # v1: pre-engine
            ver, idx, st, pv, dec, alpha, n_total, complete = leaves
            if int(ver) != 1:
                raise ValueError(
                    f"cache entry {path} declares version {int(ver)} "
                    "in an 8-leaf (v1) layout")
            engine = "bonferroni"
            version = 1
        else:
            raise ValueError(f"cache entry {path} has {len(leaves)} "
                             "leaves; expected 8 (v1) or 9 (v2)")
        results = {int(i): (float(s), float(p))
                   for i, s, p in zip(np.asarray(idx, np.int32),
                                      np.asarray(st, np.float64),
                                      np.asarray(pv, np.float64))}
        return cls(results, _CODE_DECISION[int(dec)], float(alpha),
                   int(n_total), bool(int(complete)), engine, version)

    def save(self, path: str) -> None:
        """Write the 9-leaf v2 wire layout (atomic — ``ckpt_io.save``)."""
        idx = np.asarray(sorted(self.results), np.int32)
        ckpt_io.save(path, [
            np.int64(CACHE_VERSION), idx,
            np.asarray([self.results[int(i)][0] for i in idx], np.float64),
            np.asarray([self.results[int(i)][1] for i in idx], np.float64),
            np.int8(_DECISION_CODE[self.decision]),
            np.float64(self.alpha), np.int64(self.n_total),
            np.int8(1 if self.complete else 0),
            np.asarray([self.engine.encode()])])


class ResultCache:
    """Digest-keyed verdict memo, in-memory with optional persistence.

    With a ``root`` directory every ``put`` also writes
    ``<root>/<digest>.ck`` and a cold ``get`` falls through to disk — a
    restarted daemon (or a second one sharing the directory) serves the
    whole fleet's history. ``hits``/``misses`` count lookups for the
    serve report; a disk fall-through still counts as a hit."""

    def __init__(self, root: Optional[str] = None):
        self.root = root
        if root:
            os.makedirs(root, exist_ok=True)
        self._mem: Dict[str, CacheEntry] = {}
        self.hits = 0
        self.misses = 0

    def _path(self, digest: str) -> Optional[str]:
        return os.path.join(self.root, f"{digest}.ck") if self.root else None

    def get(self, digest: str,
            stop_on_verdict: bool = False) -> Optional[CacheEntry]:
        """The entry for ``digest`` when one exists AND it can serve
        this client (``CacheEntry.serves``); ``None`` counts a miss."""
        entry = self._mem.get(digest)
        if entry is None:
            path = self._path(digest)
            if path and os.path.exists(path):
                entry = CacheEntry.load(path)
                self._mem[digest] = entry
        if entry is not None and entry.serves(stop_on_verdict):
            self.hits += 1
            return entry
        self.misses += 1
        return None

    def put(self, digest: str, entry: CacheEntry) -> None:
        """Memoize (and persist) one cell's outcome. A complete entry
        never downgrades to a partial one — an adaptive resubmission of
        an already fully-screened cell must not erase the full report."""
        old = self._mem.get(digest)
        if old is None:
            path = self._path(digest)
            if path and os.path.exists(path):
                old = CacheEntry.load(path)
        if old is not None and old.complete and not entry.complete:
            return
        self._mem[digest] = entry
        path = self._path(digest)
        if path:
            entry.save(path)

    def __len__(self) -> int:
        """Entries currently held in memory."""
        return len(self._mem)

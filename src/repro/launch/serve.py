"""Serve daemon CLI — screening-as-a-service over one pool session.

  PYTHONPATH=src python -m repro.launch.serve \
      --submit specs.json --state serve-state --workers 8 --json out.json

``--submit`` takes a JSON file holding a LIST of submission dicts; each
dict is one client's spec (one ticket). Run submissions::

  {"battery": "smallcrush", "gen": "splitmix64", "seed": 7,
   "scale": 0.25, "policy": "lpt", "alpha": 0.01, "adaptive": false,
   "backend": "auto", "offset": 0, "retries": 2}

(only ``battery`` and ``gen`` are required; ``gen`` may be a
comma-separated list for a multi-generator spec on ONE ticket).
Campaign submissions set ``"kind": "campaign"`` plus the campaign
fields (``streams``, ``waves``, ``ledger``, ``stream_check``).

The daemon coalesces compatible submissions into shared dispatches
(admission batching, window from ``--max-wait``) and serves repeat
submissions from the content-addressed result cache persisted under
``--state`` — resubmitting a finished spec costs ZERO dispatches, and
a daemon restarted on the same ``--state`` resumes in-flight batches
from their checkpoints (DESIGN.md §10). ``--json`` writes the ticket
table and the daemon counters. Exit 0 iff every ticket completed.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def spec_from_dict(d: dict):
    """One submission dict (the ``--submit`` wire shape, module
    docstring) -> a ``RunSpec`` or ``CampaignSpec``."""
    from repro.core.api import CampaignSpec, RunSpec
    from repro.core.policies import RetryPolicy
    d = dict(d)
    kind = d.pop("kind", "run")
    battery = d.pop("battery")
    gens = d.pop("gen")
    if isinstance(gens, str):
        gens = tuple(g.strip() for g in gens.split(",") if g.strip())
    else:
        gens = tuple(gens)
    retry = RetryPolicy(max_retries=int(d.pop("retries", 2)))
    if kind == "campaign":
        waves = d.pop("waves", None)
        spec = CampaignSpec(
            battery, generators=gens,
            n_streams=int(d.pop("streams", 1)),
            seed=int(d.pop("seed", 42)),
            waves=(tuple(float(w) for w in waves) if waves
                   else (float(d.pop("scale", 0.25)),)),
            alpha=float(d.pop("alpha", 0.01)),
            policy=d.pop("policy", "lpt"), retry=retry,
            backend=d.pop("backend", "auto"),
            stream_check=bool(d.pop("stream_check", True)),
            ledger_path=d.pop("ledger", None))
    elif kind == "run":
        offset = int(d.pop("offset", 0))
        spec = RunSpec(
            battery, generators=gens,
            seeds=(int(d.pop("seed", 42)),),
            scale=float(d.pop("scale", 0.25)),
            policy=d.pop("policy", "lpt"), retry=retry,
            alpha=float(d.pop("alpha", 0.01)),
            stop_on_verdict=bool(d.pop("adaptive", False)),
            backend=d.pop("backend", "auto"),
            offsets=offset if offset else None)
    else:
        raise ValueError(f"unknown submission kind {kind!r}")
    if d:
        raise ValueError(f"unknown submission field(s): {sorted(d)}")
    return spec


def ticket_row(ticket) -> dict:
    """One ticket's JSON report row (status + final decisions)."""
    row = ticket.status()
    if ticket.state == "done":
        res = ticket.result()
        if ticket.kind == "campaign":
            row["survivors"] = len(res.survivors)
            row["knockouts"] = len(res.knockouts)
        else:
            runs = getattr(res, "runs", None)
            if runs is None:
                runs = {ticket.spec.generators[0]: res}
            row["verdicts"] = {g: r.verdict.decision
                               for g, r in runs.items()}
    return row


def main():
    """Entry point: read ``--submit``, drain the queue, report."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--submit", required=True,
                    help="JSON file: a list of submission dicts "
                         "(one ticket each; see module docstring)")
    ap.add_argument("--state", default=None,
                    help="daemon state dir: result cache + batch "
                         "checkpoints (restart-resumable)")
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = all local devices")
    ap.add_argument("--max-wait", dest="max_wait", type=float, default=0.0,
                    help="admission fairness bound (seconds): how long a "
                         "submission may wait for batch companions")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write the ticket table + daemon counters here")
    args = ap.parse_args()

    with open(args.submit) as f:
        submissions = json.load(f)
    if not isinstance(submissions, list) or not submissions:
        ap.error(f"--submit {args.submit}: expected a non-empty JSON list "
                 "of submission dicts")

    if args.workers > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.workers}"

    from repro.core.api import PoolSession            # noqa: E402 (after env)
    from repro.launch.mesh import make_pool_mesh      # noqa: E402
    from repro.serve import SubmissionQueue           # noqa: E402

    session = PoolSession(mesh=make_pool_mesh(args.workers or None))
    queue = SubmissionQueue(session=session, state_dir=args.state,
                            max_wait=args.max_wait)
    tickets = [queue.submit(spec_from_dict(d)) for d in submissions]
    print(f"serve: {len(tickets)} submission(s) | "
          f"{session.n_workers} worker(s) | state={args.state or '-'} "
          f"max_wait={args.max_wait:g}s")
    queue.drain()
    stats = queue.stats()
    for t in tickets:
        print(f"  {t.id}: {t.state} (batch={t.batch_id} "
              f"cache_hits={t.cache_hits})")
    print(f"batches={stats['batches']} "
          f"dispatch_rounds={stats['dispatch_rounds']} "
          f"cache_hits={stats['cache']['hits']} "
          f"traces={stats['traces']}")

    if args.json_path:
        payload = {"workers": session.n_workers, "state": args.state,
                   "max_wait": args.max_wait,
                   "tickets": [ticket_row(t) for t in tickets],
                   "stats": stats}
        os.makedirs(os.path.dirname(args.json_path) or ".", exist_ok=True)
        with open(args.json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"json report -> {args.json_path}")

    sys.exit(0 if all(t.state == "done" for t in tickets) else 1)


if __name__ == "__main__":
    main()

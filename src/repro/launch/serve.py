# repro: quarantine -- growth-seed LM launch tooling; superseded by repro.launch.battery
"""Serving driver: batched prefill + greedy decode loop."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import decode as dec
from repro.models import lm


def serve(arch: str, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen_len: int = 16, seed: int = 0):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                 (batch, prompt_len), 0, cfg.vocab_size)
    frames = (jax.random.normal(jax.random.PRNGKey(2),
                                (batch, cfg.encoder_seq, cfg.d_model))
              if cfg.family == "audio" else None)
    max_seq = prompt_len + gen_len

    prefill_fn = jax.jit(lambda p, t, f: dec.prefill(p, t, cfg,
                                                     max_seq=max_seq,
                                                     frames=f),
                         static_argnames=())
    step_fn = jax.jit(lambda p, c, t: dec.decode_step(p, c, t, cfg),
                      donate_argnames=("c",))

    t0 = time.time()
    logits, cache = prefill_fn(params, prompts, frames)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    for _ in range(gen_len - 1):
        logits, cache = step_fn(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    return toks, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    toks, dt = serve(args.arch, batch=args.batch, gen_len=args.gen)
    print(f"generated {toks.shape} tokens in {dt:.2f}s")
    print(toks[0])


if __name__ == "__main__":
    main()

# repro: quarantine -- growth-seed LM launch tooling; superseded by repro.launch.battery
"""Training driver: ``python -m repro.launch.train --arch qwen2-1.5b
--reduced --steps 50``.

Full configs target the production mesh (see dryrun.py); ``--reduced`` runs
the same loop on CPU with the smoke config. Checkpoints every
``--ckpt-every`` steps (msgpack, atomic) and restart-exactly resumes: the
synthetic data stream is keyed by step.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import io as ckpt_io
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data.synthetic import batch_at
from repro.models import lm
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import make_train_step


def train(arch: str, steps: int, reduced: bool = True, seed: int = 0,
          global_batch: int = 8, seq_len: int = 128,
          ckpt_path: str | None = None, ckpt_every: int = 25,
          log_every: int = 10, oc: OptConfig | None = None):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    oc = oc or OptConfig(lr=1e-3, warmup_steps=20, total_steps=steps)
    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    state = {"params": params,
             "opt": init_opt_state(params, jnp.dtype(cfg.adam_dtype))}
    start_step = 0
    if ckpt_path and ckpt_io.exists(ckpt_path):
        state = ckpt_io.load_into(ckpt_path, state)
        state = jax.tree_util.tree_map(jnp.asarray, state)
        start_step = int(state["opt"]["step"])
        print(f"resumed from {ckpt_path} at step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, oc), donate_argnames=("state",))
    frames_spec = ((global_batch, cfg.encoder_seq, cfg.d_model)
                   if cfg.family == "audio" else None)
    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        batch = batch_at(seed, step, global_batch, seq_len, cfg.vocab_size,
                         frames_spec)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if log_every and (step % log_every == 0 or step == steps - 1):
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(step-start_step+1):.2f}s/step)",
                  flush=True)
        if ckpt_path and (step + 1) % ckpt_every == 0:
            ckpt_io.save(ckpt_path, state)
    if ckpt_path:
        ckpt_io.save(ckpt_path, state)
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    _, losses = train(args.arch, args.steps, args.reduced,
                      global_batch=args.batch, seq_len=args.seq,
                      ckpt_path=args.ckpt)
    print(f"first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")


if __name__ == "__main__":
    main()

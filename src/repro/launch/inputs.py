# repro: quarantine -- growth-seed LM launch tooling; superseded by repro.launch.battery
"""Abstract input specs (ShapeDtypeStruct + NamedSharding) per (arch, shape).

The same pattern shannon/kernels uses: weak-type-correct, shardable, zero
device allocation. ``input_specs(arch, shape, mesh)`` returns kwargs for
``jax.jit(step).lower(**specs)``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.common.config import SHAPES, ModelConfig, ShapeConfig
from repro.distributed.sharding import (cache_shardings, data_sharding,
                                        param_shardings)
from repro.models.lm import abstract_params, init_cache


def _attach(abs_tree, sh_tree):
    return jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abs_tree, sh_tree)


def _replicated(mesh):
    return NamedSharding(mesh, PartitionSpec())


def abstract_state(cfg: ModelConfig, mesh) -> Dict[str, Any]:
    """Abstract train state {params, opt} with shardings."""
    p_abs = abstract_params(cfg)
    p_sh = param_shardings(cfg, mesh)
    params = _attach(p_abs, p_sh)
    adt = jnp.dtype(cfg.adam_dtype)
    mom = jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, adt, sharding=s),
        p_abs, p_sh)
    opt = {"m": mom, "v": mom,
           "step": jax.ShapeDtypeStruct((), jnp.int32,
                                        sharding=_replicated(mesh))}
    return {"params": params, "opt": opt}


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig, mesh):
    b, s = shape.global_batch, shape.seq_len
    dsh = data_sharding(mesh, b)
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=dsh),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=dsh),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.compute_dtype),
            sharding=dsh)
    return batch


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig, mesh):
    b, s = shape.global_batch, shape.seq_len
    cache_abs = jax.eval_shape(
        functools.partial(init_cache, cfg, b, s))
    cache_sh = cache_shardings(cfg, b, s, mesh)
    return _attach(cache_abs, cache_sh)


def input_specs(cfg: ModelConfig, shape_name: str, mesh) -> Dict[str, Any]:
    """kwargs tree for the step function of the given shape cell."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"state": abstract_state(cfg, mesh),
                "batch": abstract_batch(cfg, shape, mesh)}
    params = _attach(abstract_params(cfg), param_shardings(cfg, mesh))
    if shape.kind == "prefill":
        b = shape.global_batch
        dsh = data_sharding(mesh, b)
        out = {"params": params,
               "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32,
                                              sharding=dsh)}
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model),
                jnp.dtype(cfg.compute_dtype), sharding=dsh)
        return out
    # decode: one new token against a seq_len cache
    b = shape.global_batch
    dsh = data_sharding(mesh, b)
    return {"params": params,
            "cache": abstract_cache(cfg, shape, mesh),
            "token": jax.ShapeDtypeStruct((b, 1), jnp.int32, sharding=dsh)}

"""One-command battery CLI — the paper's `master` script on the session API.

  PYTHONPATH=src python -m repro.launch.battery \
      --battery bigcrush --gen splitmix64 --workers 8 --scale 0.05

``--gen`` takes a comma-separated list: several generators are assessed in
ONE dispatch per round (the pool vmaps the job over a gen_ids axis).
Set ``--workers N`` (>1) to fork the pool onto N forced host devices (the
dry-run trick, battery-sized); on a real TPU pod the same code runs on the
flattened device mesh. Checkpoints progress per round; re-running the same
command resumes (only missing tests execute). ``--json PATH`` writes a
machine-readable report next to the text one.

``--source file:PATH[:fmt]`` screens a CAPTURED bitstream (a file of
raw uint32 words, ``fmt`` ``npy`` or ``u32``) through the same battery
machinery: the file becomes a ``CapturedSource`` position riding
alongside any ``--gen`` positions, its verdict bitwise what the
in-repo generator of the same bits would earn. ``--register
PKG.MOD:FN`` imports and calls a registration hook before the run, so
external generators (``repro.rng.sources.register_generator``) are
valid ``--gen`` names — the plugin seam of DESIGN.md §11.

``--adaptive`` switches to the early-stopping execution mode: the
adaptive schedule policy front-loads cheap discriminating tests, the
sequential verdict engine (alpha from ``--alpha``) decides
PASS/FAIL/UNDECIDED after every round, and pending rounds for a
definitively-failed generator are cancelled instead of dispatched.

``--verdict-engine {bonferroni,evalue}`` picks the verdict engine
(DESIGN.md §13): ``evalue`` scores every test's p-value as an e-value
and multiplies them into an anytime-valid wealth process — FAIL the
moment wealth crosses 1/alpha — and the ``--json`` payload gains an
``"evidence"`` section with each generator's wealth trajectory.

``--backend {auto,reference,accelerated}`` picks the test-kernel
implementation (stats/backends.py): ``accelerated`` routes the counting
hot loops through the Pallas kernels, ``auto`` does so only on real TPU
hardware. The choice (and its resolution) is recorded in ``--json``.

``--resize-at ROUND:WIDTH[,ROUND:WIDTH...]`` demonstrates elastic
re-meshing (the paper's opportunistic pool — machines join and vacate
mid-battery): after the given round the pool is resized to WIDTH and
the remaining rounds replan onto it, e.g. ``--resize-at 2:4,5:8`` for a
pool that shrinks to 4 workers after round 2 and grows back to 8 after
round 5. Stitched p-values are bitwise identical to a fixed-width run.

``--serve`` routes the run through the screening service
(``repro.serve``, DESIGN.md §10): each ``--gen`` entry is submitted as
its OWN ticket — separate clients — and the submission queue coalesces
them into one shared dispatch per round, memoizing every cell in the
content-addressed result cache under ``--serve-state``.
``--serve-resubmit`` submits the first generator's spec a second time
after completion to demonstrate the cache path (zero added
dispatches); the ``--json`` payload gains a ``"serve"`` section with
the ticket table, batch/dispatch counters and cache traffic.

``--campaign`` switches to generator-FLEET screening (DESIGN.md §8):
the ``--gen`` list x ``--streams`` sub-stream offsets are screened in
``--waves`` battery scales (cheapest first), failed cells knocked out
of later waves, the inter-stream seam check run as phase 0::

  PYTHONPATH=src python -m repro.launch.battery --campaign \
      --battery smallcrush --gen splitmix64,pcg32,randu --streams 4 \
      --waves 0.125,0.5 --ledger campaign.ck --json report.json

The output is the per-cell PASS/FAIL matrix; ``--ledger`` makes the
campaign resumable (knocked-out cells stay knocked out across restarts).
"""
import argparse
import json
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--battery", default="smallcrush",
                    choices=["smallcrush", "crush", "bigcrush"])
    ap.add_argument("--gen", default=None,
                    help="generator name, or comma-separated list for "
                         "multi-generator fan-out in one dispatch "
                         "(default: splitmix64 when no --source is given)")
    ap.add_argument("--source", action="append", default=None,
                    metavar="file:PATH[:FMT]",
                    help="screen a captured bitstream: file:PATH[:fmt], "
                         "fmt 'npy' (uint32 array, 2-D = one stream per "
                         "row) or 'u32' (raw little-endian words); "
                         "repeatable — each file rides alongside the "
                         "--gen positions in the same dispatch")
    ap.add_argument("--register", action="append", default=None,
                    metavar="PKG.MOD:FN",
                    help="import PKG.MOD and call FN() before the run; "
                         "the hook registers external generators via "
                         "repro.rng.sources.register_generator, making "
                         "them valid --gen names")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = all local devices")
    ap.add_argument("--policy", "--mode", dest="policy", default="lpt",
                    choices=["lpt", "roundrobin", "over_decompose",
                             "adaptive"])
    ap.add_argument("--adaptive", action="store_true",
                    help="early-stopping mode: adaptive schedule order + "
                         "stop_on_verdict (cancel a generator's pending "
                         "rounds once its verdict is definitive)")
    ap.add_argument("--alpha", type=float, default=0.01,
                    help="family-wise error rate the sequential verdict "
                         "engine spends across the battery")
    ap.add_argument("--verdict-engine", dest="verdict_engine",
                    default="bonferroni",
                    choices=["bonferroni", "evalue"],
                    help="verdict engine (core/stitch.py registry): "
                         "bonferroni = the classic sequential test, "
                         "evalue = anytime-valid e-process wealth "
                         "(core/evidence.py); evalue adds an 'evidence' "
                         "section with wealth trajectories to --json")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "reference", "accelerated"],
                    help="test-kernel backend (stats/backends.py): "
                         "reference = pure-jnp, accelerated = Pallas "
                         "kernels, auto = accelerated on real TPU only")
    ap.add_argument("--retries", type=int, default=2)
    ap.add_argument("--inject", default=None, metavar="PLAN.json",
                    help="chaos-test the run under a deterministic fault "
                         "plan (core/faults.py JSON: seeded rules of kind "
                         "evict/corrupt/straggle/lose_worker); the --json "
                         "payload gains a 'faults' ledger. Recovered runs "
                         "are bitwise identical to the clean run "
                         "(DESIGN.md §12)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resize-at", dest="resize_at", default=None,
                    help="comma-separated ROUND:WIDTH pairs — resize the "
                         "pool to WIDTH workers once ROUND rounds have "
                         "run (elastic re-meshing demo)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write a machine-readable report to this path")
    ap.add_argument("--campaign", action="store_true",
                    help="generator-fleet screening: the --gen list x "
                         "--streams sub-streams screened in --waves "
                         "scales with knockout (core/campaign.py)")
    ap.add_argument("--streams", type=int, default=1,
                    help="sub-stream offsets per generator in a campaign "
                         "grid (requires counter-based generators)")
    ap.add_argument("--waves", default=None,
                    help="comma-separated wave scales for --campaign "
                         "(default: one wave at --scale)")
    ap.add_argument("--ledger", default=None,
                    help="campaign ledger path (resumable screening)")
    ap.add_argument("--no-stream-check", dest="stream_check",
                    action="store_false",
                    help="skip the pairstream seam phase of a campaign")
    ap.add_argument("--serve", action="store_true",
                    help="submit through the screening service: one "
                         "ticket per --gen entry, coalesced by the "
                         "admission batcher, memoized in the result "
                         "cache (repro.serve)")
    ap.add_argument("--serve-state", dest="serve_state", default=None,
                    help="serve state dir (result cache + batch "
                         "checkpoints; restart-resumable)")
    ap.add_argument("--serve-resubmit", dest="serve_resubmit",
                    action="store_true",
                    help="resubmit the first generator's spec after "
                         "completion (cache-hit demo: zero dispatches)")
    ap.add_argument("--serve-max-wait", dest="serve_max_wait",
                    type=float, default=0.0,
                    help="admission fairness bound (seconds) for --serve")
    args = ap.parse_args()
    if not args.serve:
        for flag, default, name in ((args.serve_state, None,
                                     "--serve-state"),
                                    (args.serve_resubmit, False,
                                     "--serve-resubmit"),
                                    (args.serve_max_wait, 0.0,
                                     "--serve-max-wait")):
            if flag != default:
                ap.error(f"{name} only applies with --serve")
    elif args.campaign or args.resize_at or args.ckpt:
        ap.error("--serve cannot combine with --campaign/--resize-at/"
                 "--ckpt (serve batches own their checkpoints under "
                 "--serve-state)")
    if not args.campaign:
        for flag, default, name in ((args.waves, None, "--waves"),
                                    (args.streams, 1, "--streams"),
                                    (args.ledger, None, "--ledger"),
                                    (args.stream_check, True,
                                     "--no-stream-check")):
            if flag != default:
                ap.error(f"{name} only applies with --campaign")
    if args.inject and (args.serve or args.campaign):
        ap.error("--inject only applies to the classic run path (serve/"
                 "campaign chaos testing is driven through the library: "
                 "SubmissionQueue(inject=...))")
    if args.adaptive:
        if args.policy not in ("lpt", "adaptive"):
            ap.error(f"--adaptive selects the adaptive schedule policy; "
                     f"it cannot be combined with --policy {args.policy}")
        args.policy = "adaptive"
    resize_at = {}
    if args.resize_at:
        try:
            for tok in args.resize_at.split(","):
                rnd, width = tok.strip().split(":")
                resize_at[int(rnd)] = int(width)
        except ValueError:
            ap.error(f"--resize-at wants ROUND:WIDTH[,ROUND:WIDTH...], "
                     f"got {args.resize_at!r}")
        if any(w < 1 for w in resize_at.values()):
            ap.error("--resize-at widths must be >= 1")

    # the forced host-device pool must cover the widest point of the run
    need = max([args.workers] + list(resize_at.values()))
    if need > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={need}"

    from repro.core import stitch                     # noqa: E402 (after env)
    from repro.core.api import (                      # noqa: E402
        BatteryResult, CampaignSpec, PoolSession, RunSpec)
    from repro.core.faults import FaultPlan           # noqa: E402
    from repro.core.policies import (                 # noqa: E402
        RetryBudgetExhausted, RetryPolicy)
    from repro.launch.mesh import make_pool_mesh      # noqa: E402

    from repro.stats import backends as kernel_backends  # noqa: E402

    # external-generator hooks run BEFORE any spec resolves names, so a
    # --gen entry a hook registers is indistinguishable from a built-in
    if args.register:
        import importlib                              # noqa: E402
        for hook in args.register:
            mod_name, sep, fn_name = hook.partition(":")
            if not sep or not mod_name or not fn_name:
                ap.error(f"--register wants PKG.MOD:FN, got {hook!r}")
            try:
                fn = getattr(importlib.import_module(mod_name), fn_name)
            except (ImportError, AttributeError) as exc:
                ap.error(f"--register {hook!r}: {exc}")
            fn()

    gens = (tuple(g.strip() for g in args.gen.split(",") if g.strip())
            if args.gen else ())
    source_specs = tuple(args.source or ())
    if not gens and not source_specs:
        gens = ("splitmix64",)
    positions = gens + source_specs
    session = PoolSession(mesh=make_pool_mesh(args.workers or None))

    if args.campaign:
        if args.adaptive or args.resize_at or args.ckpt:
            ap.error("--campaign cannot combine with --adaptive/"
                     "--resize-at/--ckpt (its own ledger handles resume)")
        from repro.core.campaign import Campaign      # noqa: E402
        waves = (tuple(float(w) for w in args.waves.split(","))
                 if args.waves else (args.scale,))
        cspec = CampaignSpec(
            args.battery, sources=positions, n_streams=args.streams,
            seed=args.seed, waves=waves, alpha=args.alpha,
            policy=args.policy,
            retry=RetryPolicy(max_retries=args.retries),
            backend=args.backend,
            stream_check=args.stream_check, ledger_path=args.ledger,
            progress=True, verdict_engine=args.verdict_engine)
        campaign = Campaign(session, cspec)
        print(f"campaign: {len(cspec.generators)} source(s) x {args.streams} "
              f"stream(s) | battery={args.battery} waves={waves} "
              f"span={campaign.span} policy={args.policy} "
              f"backend={args.backend}")
        res = campaign.run()
        print(res.report)
        print(f"\nwall={res.wall_s:.1f}s rounds={res.rounds_run} "
              f"traces={session.total_traces}")
        n_open = len(res.cells) - len(res.survivors) - len(res.knockouts)
        if args.json_path:
            payload = {
                "battery": args.battery, "workers": session.n_workers,
                "policy": args.policy, "backend": args.backend,
                "backend_resolved": kernel_backends.resolve(args.backend),
                "alpha": args.alpha, "seed": args.seed,
                "wall_s": round(res.wall_s, 3),
                "rounds_run": res.rounds_run,
                "campaign": {
                    "n_streams": args.streams, "waves": list(waves),
                    **({"sources": [
                        {"spec": raw, "uid": src.uid()}
                        for raw, src in zip(
                            source_specs,
                            cspec.sources[len(gens):])]}
                       if args.source else {}),
                    "span": campaign.span,
                    "phases": res.phase_names,
                    "stream_check": args.stream_check,
                    "survivors": len(res.survivors),
                    "knockouts": len(res.knockouts),
                    "undecided": n_open,
                    "cells": [
                        {"gen": g, "stream": s,
                         "decision": res.decision(g, s),
                         "phase": (int(res.decided_phase[i])
                                   if res.decided_phase[i] >= 0 else None)}
                        for i, (g, s) in enumerate(res.cells)],
                },
            }
            if args.verdict_engine != "bonferroni":
                # conditional section: golden-key consumers of the
                # classic campaign payload see exactly the historical keys
                payload["evidence"] = {
                    "engine": args.verdict_engine,
                    "threshold": 1.0 / args.alpha,
                    "continuations": res.continuations,
                    "cells": [
                        {"gen": g, "stream": s,
                         "wealth": float(res.wealth[i]),
                         "log_wealth": float(res.log_wealth[i])}
                        for i, (g, s) in enumerate(res.cells)]}
            os.makedirs(os.path.dirname(args.json_path) or ".",
                        exist_ok=True)
            with open(args.json_path, "w") as f:
                json.dump(payload, f, indent=2)
            print(f"json report -> {args.json_path}")
        # a completed campaign exits 0 (knockouts are the product, not an
        # error); undecided cells mean the screening did not finish
        sys.exit(0 if n_open == 0 else 1)
    launch_workers = session.n_workers          # width before any resize
    fault_plan = None
    if args.inject:
        try:
            fault_plan = FaultPlan.load(args.inject)
        except (OSError, ValueError, TypeError, KeyError) as exc:
            ap.error(f"--inject {args.inject!r}: {exc}")
    spec = RunSpec(args.battery, sources=positions, seeds=(args.seed,),
                   scale=args.scale, policy=args.policy,
                   retry=RetryPolicy(max_retries=args.retries),
                   checkpoint_path=args.ckpt, progress=True,
                   alpha=args.alpha, stop_on_verdict=args.adaptive,
                   verdict_engine=args.verdict_engine,
                   backend=args.backend, inject=fault_plan)
    names = spec.generators
    backend_resolved = kernel_backends.resolve(args.backend)
    print(f"pool: {session.n_workers} workers | battery={args.battery} "
          f"gen={','.join(names)} scale={args.scale} policy={args.policy} "
          f"backend={args.backend}"
          + (f"->{backend_resolved}" if args.backend == "auto" else "")
          + (f" adaptive(alpha={args.alpha})" if args.adaptive else ""))

    resizes = []
    serve_info = None
    if args.serve:
        from repro.serve import SubmissionQueue       # noqa: E402
        queue = SubmissionQueue(session=session,
                                state_dir=args.serve_state,
                                max_wait=args.serve_max_wait)
        # one ticket per source position: independent clients whose
        # compatible specs the admission batcher coalesces into shared
        # dispatches
        gen_specs = [RunSpec(args.battery, sources=(p,),
                             seeds=(args.seed,), scale=args.scale,
                             policy=args.policy,
                             retry=RetryPolicy(max_retries=args.retries),
                             alpha=args.alpha,
                             stop_on_verdict=args.adaptive,
                             verdict_engine=args.verdict_engine,
                             backend=args.backend) for p in positions]
        tickets = [queue.submit(s) for s in gen_specs]
        queue.drain()
        runs = {g: t.result() for g, t in zip(names, tickets)}
        resubmit = None
        if args.serve_resubmit:
            before = queue.dispatch_rounds
            rticket = queue.submit(gen_specs[0])
            done_at_submit = rticket.done
            queue.drain()
            rticket.result()
            resubmit = {"ticket": rticket.id,
                        "cache_hits": rticket.cache_hits,
                        "done_at_submit": done_at_submit,
                        "dispatches_added": queue.dispatch_rounds - before}
            print(f"  resubmit {names[0]}: cache_hits="
                  f"{rticket.cache_hits} dispatches_added="
                  f"{resubmit['dispatches_added']}")
        stats = queue.stats()
        serve_info = {
            "state": args.serve_state, "max_wait": args.serve_max_wait,
            "tickets": [{"ticket": t.id, "gen": g, "state": t.state,
                         "batch": t.batch_id, "cache_hits": t.cache_hits}
                        for g, t in zip(names, tickets)],
            "batches": stats["batches"],
            "dispatch_rounds": stats["dispatch_rounds"],
            "cache": stats["cache"], "traces": stats["traces"],
            "resubmit": resubmit}
        print(f"serve: {len(tickets)} ticket(s) -> "
              f"{stats['batches']} batch(es), "
              f"{stats['dispatch_rounds']} dispatch round(s), "
              f"{stats['cache']['hits']} cache hit(s)")
        wall_s = max(r.wall_s for r in runs.values())
        rounds_run = max(r.rounds_run for r in runs.values())
        retries_total = max(r.retries for r in runs.values())
    else:
        handle = session.submit(spec)
        for rnd in sorted(resize_at):           # elastic re-meshing demo
            while handle.pending_rounds and handle.rounds_run < rnd:
                handle.poll()
            if handle.pending_rounds:
                session.resize(resize_at[rnd])
                resizes.append({"round": handle.rounds_run,
                                "workers": resize_at[rnd]})
                print(f"  resize: pool -> {resize_at[rnd]} workers after "
                      f"round {handle.rounds_run}")
        try:
            res = handle.result()
        except RetryBudgetExhausted as exc:
            print(f"error: {exc}", file=sys.stderr)
            sys.exit(2)
        multi = isinstance(res, BatteryResult)
        runs = res.runs if multi else {names[0]: res}
        wall_s, rounds_run = res.wall_s, res.rounds_run
        retries_total = res.retries
    for run in runs.values():
        print(run.report)
    for gen, run in runs.items():
        print(f"verdict[{gen}]: {run.verdict}")
    print(f"\nwall={wall_s:.1f}s rounds={rounds_run}"
          f"/{next(iter(runs.values())).plan_rounds}"
          f" retries={retries_total}")

    if args.json_path:
        entries = session.entries(spec)
        payload = {
            "battery": args.battery, "scale": args.scale,
            "workers": launch_workers, "policy": args.policy,
            "backend": args.backend,
            "backend_resolved": backend_resolved,
            "adaptive": args.adaptive, "alpha": args.alpha,
            "resizes": resizes,
            "seed": args.seed, "wall_s": round(wall_s, 3),
            "rounds_run": rounds_run, "retries": retries_total,
            "plan_rounds": next(iter(runs.values())).plan_rounds,
            "runs": {},
        }
        if serve_info is not None:
            payload["serve"] = serve_info
        if args.verdict_engine != "bonferroni":
            # only present under a non-default engine: the wealth
            # trajectories the anytime-valid verdicts were read off
            payload["evidence"] = {
                "engine": args.verdict_engine,
                "threshold": 1.0 / args.alpha,
                "runs": {gen: {"wealth": run.verdict.wealth,
                               "log_wealth": run.verdict.log_wealth,
                               "trajectory": list(run.verdict.trajectory)}
                         for gen, run in runs.items()}}
        if args.source:
            # only present when --source was used: golden-key consumers
            # of the classic payload see exactly the historical keys
            payload["sources"] = [
                {"spec": raw, "uid": src.uid()}
                for raw, src in zip(source_specs,
                                    spec.sources[len(gens):])]
        if args.inject:
            # only present under --inject (which forbids --serve, so
            # `handle` is guaranteed bound): the fault/quarantine ledger
            payload["faults"] = {
                "plan": fault_plan.to_dict(),
                "events": [e.to_dict() for e in handle.fault_events],
                "quarantines": list(handle.quarantines)}
        for gen, run in runs.items():
            tests = []
            for e in entries:
                stat, p = run.results.get(e.index, (None, None))
                suspect = (p is not None
                           and (p < stitch.SUSPECT_P
                                or p > 1 - stitch.SUSPECT_P))
                tests.append({"index": e.index, "name": e.name,
                              "stat": stat, "p": p, "suspect": suspect})
            v = run.verdict
            payload["runs"][gen] = {
                "suspects": run.n_suspect,
                "verdict": v.decision,
                "tests_checked": v.n_checked,
                "failed_tests": list(v.failed_tests),
                "rounds_run": run.rounds_run,
                "tests": tests}
        os.makedirs(os.path.dirname(args.json_path) or ".", exist_ok=True)
        with open(args.json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"json report -> {args.json_path}")

    # classic contract: exit 1 iff any suspect p-value. An adaptive run may
    # have cancelled before producing suspects-in-report for every failed
    # generator, so there the sequential verdict also gates the exit code
    # (its alpha/2n boundary is looser than SUSPECT_P — applying it to
    # non-adaptive runs would contradict the printed report).
    suspects = sum(run.n_suspect for run in runs.values())
    failed = ((args.adaptive or args.verdict_engine != "bonferroni")
              and any(run.verdict.decision == "FAIL"
                      for run in runs.values()))
    sys.exit(0 if suspects == 0 and not failed else 1)


if __name__ == "__main__":
    main()

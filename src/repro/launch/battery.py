"""One-command battery CLI — the paper's `master` script.

  PYTHONPATH=src python -m repro.launch.battery \
      --battery bigcrush --gen splitmix64 --workers 8 --scale 0.05

Set ``--workers N`` (>1) to fork the pool onto N forced host devices (the
dry-run trick, battery-sized); on a real TPU pod the same code runs on the
flattened device mesh. Checkpoints progress per round; re-running the same
command resumes (only missing tests execute).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--battery", default="smallcrush",
                    choices=["smallcrush", "crush", "bigcrush"])
    ap.add_argument("--gen", default="splitmix64")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = all local devices")
    ap.add_argument("--mode", default="lpt", choices=["lpt", "roundrobin"])
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.workers > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.workers}"

    from repro.core.queue import run_battery          # noqa: E402 (after env)
    from repro.launch.mesh import make_pool_mesh      # noqa: E402

    mesh = make_pool_mesh(args.workers or None)
    print(f"pool: {mesh.devices.size} workers | battery={args.battery} "
          f"gen={args.gen} scale={args.scale} mode={args.mode}")
    res = run_battery(args.battery, args.gen, args.seed, mesh,
                      scale=args.scale, mode=args.mode,
                      checkpoint_path=args.ckpt, progress=True)
    print(res.report)
    print(f"\nwall={res.wall_s:.1f}s rounds={res.rounds_run} "
          f"retries={res.retries}")
    suspects = res.report.count("SUSPECT")
    sys.exit(0 if suspects == 0 else 1)


if __name__ == "__main__":
    main()

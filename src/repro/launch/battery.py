"""One-command battery CLI — the paper's `master` script on the session API.

  PYTHONPATH=src python -m repro.launch.battery \
      --battery bigcrush --gen splitmix64 --workers 8 --scale 0.05

``--gen`` takes a comma-separated list: several generators are assessed in
ONE dispatch per round (the pool vmaps the job over a gen_ids axis).
Set ``--workers N`` (>1) to fork the pool onto N forced host devices (the
dry-run trick, battery-sized); on a real TPU pod the same code runs on the
flattened device mesh. Checkpoints progress per round; re-running the same
command resumes (only missing tests execute). ``--json PATH`` writes a
machine-readable report next to the text one.
"""
import argparse
import json
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--battery", default="smallcrush",
                    choices=["smallcrush", "crush", "bigcrush"])
    ap.add_argument("--gen", default="splitmix64",
                    help="generator name, or comma-separated list for "
                         "multi-generator fan-out in one dispatch")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--workers", type=int, default=0,
                    help="0 = all local devices")
    ap.add_argument("--policy", "--mode", dest="policy", default="lpt",
                    choices=["lpt", "roundrobin", "over_decompose"])
    ap.add_argument("--retries", type=int, default=2)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write a machine-readable report to this path")
    args = ap.parse_args()

    if args.workers > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={args.workers}"

    from repro.core import stitch                     # noqa: E402 (after env)
    from repro.core.api import (                      # noqa: E402
        BatteryResult, PoolSession, RunSpec)
    from repro.core.policies import RetryPolicy       # noqa: E402
    from repro.launch.mesh import make_pool_mesh      # noqa: E402

    gens = tuple(g.strip() for g in args.gen.split(",") if g.strip())
    session = PoolSession(mesh=make_pool_mesh(args.workers or None))
    spec = RunSpec(args.battery, generators=gens, seeds=(args.seed,),
                   scale=args.scale, policy=args.policy,
                   retry=RetryPolicy(max_retries=args.retries),
                   checkpoint_path=args.ckpt, progress=True)
    print(f"pool: {session.n_workers} workers | battery={args.battery} "
          f"gen={','.join(gens)} scale={args.scale} policy={args.policy}")

    res = session.submit(spec).result()
    multi = isinstance(res, BatteryResult)
    runs = res.runs if multi else {gens[0]: res}
    for run in runs.values():
        print(run.report)
    print(f"\nwall={res.wall_s:.1f}s rounds={res.rounds_run} "
          f"retries={res.retries}")

    if args.json_path:
        entries = session.entries(spec)
        payload = {
            "battery": args.battery, "scale": args.scale,
            "workers": session.n_workers, "policy": args.policy,
            "seed": args.seed, "wall_s": round(res.wall_s, 3),
            "rounds_run": res.rounds_run, "retries": res.retries,
            "runs": {},
        }
        for gen, run in runs.items():
            tests = []
            for e in entries:
                stat, p = run.results.get(e.index, (None, None))
                suspect = (p is not None
                           and (p < stitch.SUSPECT_P
                                or p > 1 - stitch.SUSPECT_P))
                tests.append({"index": e.index, "name": e.name,
                              "stat": stat, "p": p, "suspect": suspect})
            payload["runs"][gen] = {"suspects": run.n_suspect,
                                    "verdict": ("FAIL" if run.n_suspect
                                                else "pass"),
                                    "tests": tests}
        os.makedirs(os.path.dirname(args.json_path) or ".", exist_ok=True)
        with open(args.json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"json report -> {args.json_path}")

    suspects = sum(run.n_suspect for run in runs.values())
    sys.exit(0 if suspects == 0 else 1)


if __name__ == "__main__":
    main()

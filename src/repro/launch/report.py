# repro: quarantine -- growth-seed LM launch tooling; superseded by repro.launch.battery
"""Render EXPERIMENTS.md tables from artifacts/dryrun/*.json.

    PYTHONPATH=src python -m repro.launch.report > artifacts/roofline.md
"""
from __future__ import annotations

import glob
import json

from repro.common.config import SHAPES
from repro.configs import ARCH_IDS


def load():
    recs = {}
    for f in glob.glob("artifacts/dryrun/*.json"):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_b(x):
    if x is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(x) >= div:
            return f"{x / div:.1f}{unit}"
    return f"{x:.0f}B"


def roofline_table(recs) -> str:
    head = ("| arch | shape | dom | compute_s | memory_s | collective_s | "
            "6ND/compiled | roofline_frac | coll bytes | HLO flops(raw) |\n"
            "|---|---|---|---|---|---|---|---|---|---|")
    lines = [head]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = recs.get((arch, shape, "single"))
            if r is None:
                lines.append(f"| {arch} | {shape} | MISSING | | | | | | | |")
                continue
            if r["status"] == "skip":
                lines.append(f"| {arch} | {shape} | skip(long) — "
                             f"{r['reason'][:40]}… | | | | | | | |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | ERROR | | | | | | | |")
                continue
            t = r["roofline"]
            lines.append(
                f"| {arch} | {shape} | {t['dominant']} | "
                f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | "
                f"{t['collective_s']:.2e} | {t['useful_ratio']:.3f} | "
                f"{t['roofline_fraction']:.3f} | "
                f"{fmt_b(t['collective_bytes'])} | "
                f"{fmt_b(r['cost_analysis']['flops_raw'])} |")
    return "\n".join(lines)


def dryrun_table(recs) -> str:
    head = ("| arch | shape | mesh | status | compile_s | arg bytes/dev | "
            "temp bytes/dev | collectives |\n|---|---|---|---|---|---|---|---|")
    lines = [head]
    for arch in ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    continue
                if r["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | "
                                 f"{r['status']} | | | | |")
                    continue
                colls = ", ".join(f"{k.split('-')[-1][:7]}:{fmt_b(v)}"
                                  for k, v in sorted(r["collectives"].items())
                                  if v > 1e6)
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | "
                    f"{r['compile_s']:.0f} | "
                    f"{fmt_b(r['memory']['argument_bytes'])} | "
                    f"{fmt_b(r['memory']['temp_bytes'])} | {colls or '-'} |")
    return "\n".join(lines)


def main():
    recs = load()
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    skip = sum(1 for r in recs.values() if r["status"] == "skip")
    print(f"<!-- {ok} ok, {skip} skip, {len(recs)} cells -->\n")
    print("## Roofline (single-pod 16x16, per global step)\n")
    print(roofline_table(recs))
    print("\n## Dry-run (both meshes)\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()

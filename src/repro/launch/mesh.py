"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not module-level state) so importing
this module never touches jax device initialization. The dry-run driver sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before importing jax
(see dryrun.py); smoke tests and benches see the real single CPU device.

Mesh layout (TPU v5e-class pods of 256 chips):
  single pod : (16, 16)        axes ("data", "model")
  multi pod  : (2, 16, 16)     axes ("pod", "data", "model")
The battery pool (the paper's HTCondor-pool analogue) uses the flattened
"workers" view of the same device set — see ``repro.core.pool``.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.common.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (dry-run only)")
    return make_mesh(shape, axes, devices=devices[:n])


def make_pool_mesh(n_workers: int | None = None):
    """Flat 1-D mesh for the battery pool ('workers' axis).

    ``PoolSession.resize`` calls this for every width the pool bounces
    through, so the width must be validated here — a clear error beats
    ``make_mesh`` failing on a short device slice."""
    devices = jax.devices()
    n = n_workers or len(devices)
    if n < 1:
        raise ValueError(f"pool width must be >= 1, got {n}")
    if n > len(devices):
        raise RuntimeError(
            f"pool of {n} workers needs {n} devices, have {len(devices)}; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            "before importing jax (dry-run only)")
    return make_mesh((n,), ("workers",), devices=devices[:n])

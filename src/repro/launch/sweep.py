# repro: quarantine -- growth-seed LM launch tooling; superseded by repro.launch.battery
"""Dry-run sweep driver: every (arch × shape × mesh) cell as a subprocess.

Each cell runs in its own process (jax device-count env is per-process) with
a timeout; results land in ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``
and are skipped when already present (restartable — the same
completed-work-bitmap discipline the battery checkpointing uses).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed

from repro.common.config import SHAPES
from repro.configs import ARCH_IDS

ART = "artifacts/dryrun"


def cell_path(arch, shape, mesh):
    return f"{ART}/{arch}__{shape}__{mesh}.json"


def run_one(arch, shape, mesh, timeout, force=False):
    out = cell_path(arch, shape, mesh)
    if not force and os.path.exists(out):
        with open(out) as f:
            rec = json.load(f)
        if rec.get("status") in ("ok", "skip"):
            return arch, shape, mesh, rec.get("status"), 0.0
    env = dict(os.environ, PYTHONPATH="src")
    t0 = time.time()
    try:
        p = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--mesh", mesh, "--out", out],
            env=env, capture_output=True, text=True, timeout=timeout)
        status = "ok" if p.returncode == 0 else "error"
        if p.returncode != 0 and not os.path.exists(out):
            os.makedirs(ART, exist_ok=True)
            with open(out, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "error",
                           "error": p.stderr[-3000:]}, f, indent=1)
    except subprocess.TimeoutExpired:
        status = "timeout"
        os.makedirs(ART, exist_ok=True)
        with open(out, "w") as f:
            json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                       "status": "timeout", "timeout_s": timeout}, f)
    return arch, shape, mesh, status, time.time() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--archs", default=",".join(ARCH_IDS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = [(a, s, m)
             for a in args.archs.split(",")
             for s in args.shapes.split(",")
             for m in args.meshes.split(",")]
    # cheapest first: small models & decode shapes compile fastest
    order = {"qwen2-1.5b": 0, "granite-moe-1b-a400m": 1, "whisper-small": 2,
             "zamba2-1.2b": 3, "xlstm-1.3b": 4, "glm4-9b": 5,
             "gemma2-27b": 6, "chameleon-34b": 7, "deepseek-v2-236b": 8,
             "nemotron-4-340b": 9}
    cells.sort(key=lambda c: (order.get(c[0], 99), c[1], c[2]))

    os.makedirs(ART, exist_ok=True)
    t0 = time.time()
    done = 0
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = {ex.submit(run_one, a, s, m, args.timeout, args.force):
                (a, s, m) for a, s, m in cells}
        for fut in as_completed(futs):
            arch, shape, mesh, status, dt = fut.result()
            done += 1
            print(f"[{done}/{len(cells)} {time.time()-t0:7.0f}s] "
                  f"{status:8s} {arch} {shape} {mesh} ({dt:.0f}s)",
                  flush=True)


if __name__ == "__main__":
    main()

# repro: quarantine -- growth-seed LM launch tooling; superseded by repro.launch.battery
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Dry-run only — tests/benches see the real device.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.common.config import SHAPES, shape_applicable        # noqa: E402
from repro.configs import ARCH_IDS, get_config                  # noqa: E402
from repro.launch.inputs import input_specs                     # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.launch.roofline import (analytic_flops,              # noqa: E402
                                   analytic_hbm_bytes,
                                   hlo_collective_bytes, roofline_terms)


def build_step(cfg, shape_name: str):
    """Returns (fn, donate_argnames) for the shape cell's step function."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        from repro.train.optim import OptConfig
        from repro.train.step import make_train_step
        step = make_train_step(cfg, OptConfig())

        def train_step(state, batch):
            return step(state, batch)

        return train_step, ("state",)
    if shape.kind == "prefill":
        from repro.models.decode import prefill

        if cfg.family == "audio":
            def prefill_step(params, tokens, frames):
                return prefill(params, tokens, cfg, frames=frames)
        else:
            def prefill_step(params, tokens):
                return prefill(params, tokens, cfg)
        return prefill_step, ()
    from repro.models.decode import decode_step

    def serve_step(params, cache, token):
        return decode_step(params, cache, token, cfg)

    return serve_step, ("cache",)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             hlo_snippet: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single"}
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    fn, donate = build_step(cfg, shape_name)
    specs = input_specs(cfg, shape_name, mesh)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, donate_argnames=donate).lower(**specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll_by_op, coll_total = hlo_collective_bytes(hlo)

    flops = analytic_flops(cfg, shape_name, compiled=True)
    useful = analytic_flops(cfg, shape_name, compiled=False)
    hbm = analytic_hbm_bytes(cfg, shape_name, n_chips)
    terms = roofline_terms(cfg, shape_name, n_chips, coll_total,
                           flops=flops, hbm_bytes=hbm)

    rec.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_peak_est": mem.argument_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
        },
        cost_analysis={"flops_raw": cost.get("flops"),
                       "bytes_raw": cost.get("bytes accessed")},
        collectives=coll_by_op,
        analytic={"flops_compiled": flops, "flops_useful": useful,
                  "hbm_bytes": hbm},
        roofline=terms,
        hlo_bytes=len(hlo),
    )
    if hlo_snippet:
        rec["hlo_head"] = hlo[:4000]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--hlo-out", default=None,
                    help="also dump full compiled HLO text here")
    args = ap.parse_args()

    try:
        rec = run_cell(args.arch, args.shape, args.mesh == "multi")
    except Exception as e:  # noqa: BLE001 — recorded as a failed cell
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}

    js = json.dumps(rec, indent=1, default=float)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(js)
    print(js[:2000])
    if rec.get("status") == "ok":
        r = rec["roofline"]
        print(f"DRYRUN OK {args.arch} {args.shape} {args.mesh}: "
              f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
              f"collective={r['collective_s']:.3e}s dom={r['dominant']} "
              f"frac={r['roofline_fraction']:.3f}")
    elif rec.get("status") == "skip":
        print(f"DRYRUN SKIP {args.arch} {args.shape}: {rec['reason']}")
    else:
        print(f"DRYRUN ERROR {args.arch} {args.shape} {args.mesh}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()

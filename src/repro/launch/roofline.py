# repro: quarantine -- growth-seed LM launch tooling; superseded by repro.launch.battery
"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e-class constants
from ``repro.common.config.HW``):

  compute    = FLOPs            / (chips * 197e12)
  memory     = HBM bytes        / (chips * 819e9)
  collective = collective bytes / (chips * links * 50e9)

Sources:
  * collective bytes — parsed from the post-SPMD HLO text, **with while-loop
    trip-count multipliers**: XLA's cost analysis (and the HLO text) contain
    each scan body once; we reconstruct the loop nest (while_cond trip
    constants + body call graph) and multiply. See ``hlo_collective_bytes``.
  * FLOPs / HBM bytes — ``compiled.cost_analysis()`` raw values are reported,
    but the roofline uses the ANALYTIC models below (cost analysis counts
    scan bodies once — calibrated in EXPERIMENTS.md §Dry-run); the analytic
    "compiled" model includes implementation overheads (masked attention
    blocks computed then discarded, MoE dense-dispatch einsums, remat
    recompute) so the MODEL_FLOPS/compiled ratio exposes the waste the perf
    loop attacks.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

from repro.common.config import HW, SHAPES, ModelConfig

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
    "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(tok_dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES[tok_dtype]


def _split_computations(hlo: str) -> Dict[str, str]:
    """Split HLO text into named computations (scheduled-HLO layout:
    ``%name (args) -> type {`` headers at column 0; ``ENTRY`` for main)."""
    comps: Dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = _COMP_RE.match(line)
            cur = m.group(1) if m else None
            if cur:
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        if cur is not None:
            comps[cur].append(line)
    out = {k: "\n".join(v) for k, v in comps.items()}
    if entry:
        out["__entry__"] = out[entry]
    return out


def _trip_count(cond_text: str) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def _collectives_in(text: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for line in text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[-1][:40]:
            continue
        op = m.group(1)
        prefix = line[:m.start()]
        shapes = _SHAPE_RE.findall(prefix)      # result type(s)
        by = sum(_shape_bytes(t, d) for t, d in shapes)
        if op == "reduce-scatter":
            # result is the scattered piece; traffic ~ the full input buffer
            operand = _SHAPE_RE.findall(line[m.end():])
            if operand:
                by = max(by, sum(_shape_bytes(t, d) for t, d in operand[:1]))
        mult = 2 if op == "all-reduce" else 1    # ring all-reduce moves ~2x
        out[op] = out.get(op, 0) + by * mult
    return out


def hlo_collective_bytes(hlo: str) -> Tuple[Dict[str, int], int]:
    """Collective bytes with while-loop multipliers. Returns (per-op, total)."""
    comps = _split_computations(hlo)
    # loop nest: computation -> [(body_name, trip)]
    children: Dict[str, list] = {}
    for name, text in comps.items():
        for cond, body in _WHILE_RE.findall(text):
            trip = _trip_count(comps.get(cond, ""))
            children.setdefault(name, []).append((body, trip))

    totals: Dict[str, int] = {}

    def visit(name: str, mult: int, seen):
        if name in seen or name not in comps:
            return
        seen = seen | {name}
        local = _collectives_in(comps[name])
        for op, by in local.items():
            totals[op] = totals.get(op, 0) + by * mult
        for body, trip in children.get(name, []):
            visit(body, mult * trip, seen)

    entry = "__entry__" if "__entry__" in comps else next(iter(comps), None)
    if entry:
        # entry text aliases a named comp; avoid double visiting via seen set
        visit(entry, 1, frozenset())
        for name, text in comps.items():
            if name != entry and comps[name] is comps.get("__entry__"):
                continue
    # subtract nothing: bodies are only reachable through while edges
    return totals, sum(totals.values())


# ---------------------------------------------------------------------------
# analytic FLOP / byte models (per global step; fwd only unless train)

def _blocked_pairs(s, kv, kind, window, qc=None, kc=1024):
    """(q,k) pairs computed by the blocked-triangle schedule in
    models/attention.py::_attend_blocked (mirrors its bounds exactly)."""
    qc = qc or max(512, s // 16)
    if s % qc:
        qc = s
    total = 0
    for i in range(s // qc):
        if kind == "bidir":
            lo, hi = 0, kv
        else:
            hi = min(kv, (i + 1) * qc)
            lo = 0
            if kind == "local":
                lo = max(0, (i * qc - window + 1) // kc * kc)
        span = -(-(hi - lo) // kc) * kc if (hi - lo) % kc else (hi - lo)
        total += qc * span
    return total


def _attn_flops(cfg, b, s, kv, causal=True, window=0, compiled=False):
    """Score+AV flops. compiled=True mirrors the blocked implementation
    (block-granular masking waste); compiled=False is the exact-mask floor."""
    h, dh = cfg.n_heads, cfg.head_dim_
    if cfg.mla:
        dh = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        dv = cfg.mla.v_head_dim
    else:
        dv = dh
    kind = "local" if window else ("causal" if causal else "bidir")
    if compiled and s > 1 and s * kv > 1024 * 1024:
        pairs = _blocked_pairs(s, kv, kind, window)
    elif window:
        pairs = s * min(kv, window)
    elif causal and s > 1:
        pairs = s * kv / 2
    else:
        pairs = s * kv
    return 2 * b * pairs * h * (dh + dv)


def _proj_flops(cfg, b, s):
    d, h, k, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    if cfg.mla:
        m = cfg.mla
        per_tok = (d * m.q_lora_rank
                   + m.q_lora_rank * h * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                   + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                   + m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
                   + h * m.v_head_dim * d)
    else:
        per_tok = d * dh * (2 * h + 2 * k)
    return 2 * b * s * per_tok


def _mlp_flops(cfg, b, s, d_ff=None):
    f = d_ff if d_ff is not None else cfg.d_ff
    mats = 3 if cfg.gated_mlp else 2
    return 2 * b * s * cfg.d_model * f * mats


def _moe_flops(cfg, b, s, compiled: bool):
    m = cfg.moe
    t = b * s
    mats = 3 if cfg.gated_mlp else 2
    useful = 2 * t * m.top_k * cfg.d_model * m.d_ff_expert * mats
    if m.n_shared:
        useful += 2 * t * cfg.d_model * (m.d_ff_shared * m.n_shared) * mats
    useful += 2 * t * cfg.d_model * m.n_experts          # router
    if not compiled:
        return useful
    # grouped scatter dispatch: no dispatch matmuls; overhead = capacity
    # padding (cf) on the routed expert GEMMs
    routed = 2 * t * m.top_k * cfg.d_model * m.d_ff_expert * mats
    return useful - routed + routed * m.capacity_factor


def _ssd_flops(cfg, b, s):
    ss = cfg.ssm
    di = ss.expand * cfg.d_model
    n, q = ss.d_state, ss.chunk
    proj = 2 * b * s * cfg.d_model * (2 * di + 2 * n + di // ss.head_dim) \
        + 2 * b * s * di * cfg.d_model
    ssd = 2 * b * s * (q * n + q * di + 2 * di * n)
    return proj + ssd


def _mlstm_flops(cfg, b, s):
    x = cfg.xlstm
    inner = int(x.proj_factor_m * cfg.d_model)
    dh = inner // cfg.n_heads
    q = x.chunk
    proj = 2 * b * s * cfg.d_model * 3 * inner + 2 * b * s * inner * dh * 3
    cell = 2 * b * s * (2 * q * inner + 3 * inner * dh)
    return proj + cell


def _slstm_flops(cfg, b, s):
    d = cfg.d_model
    dh = d // cfg.n_heads
    ffn = int(cfg.xlstm.proj_factor_s * d)
    return 2 * b * s * (4 * d * d + 4 * d * dh + 3 * d * ffn)


def analytic_flops(cfg: ModelConfig, shape_name: str,
                   compiled: bool = True) -> float:
    """Per-global-step FLOPs. compiled=True models what the implementation
    actually executes (masked blocks, dense MoE dispatch, remat recompute);
    compiled=False is the useful-work floor."""
    sh = SHAPES[shape_name]
    b = sh.global_batch
    kind = sh.kind
    s = 1 if kind == "decode" else sh.seq_len
    kv = sh.seq_len
    fam = cfg.family
    
    total = 2 * b * s * cfg.d_model * cfg.padded_vocab      # logits
    if fam in ("dense", "vlm"):
        pat = cfg.attn_pattern
        for i in range(cfg.n_layers):
            kind_i = pat[i % len(pat)]
            win = cfg.local_window if kind_i == "local" else 0
            total += _proj_flops(cfg, b, s)
            total += _attn_flops(cfg, b, s, kv, window=win, compiled=compiled)
            total += _mlp_flops(cfg, b, s)
    elif fam == "moe":
        m = cfg.moe
        for i in range(cfg.n_layers):
            total += _proj_flops(cfg, b, s)
            total += _attn_flops(cfg, b, s, kv, compiled=compiled)
            if i < m.first_dense_layers:
                total += _mlp_flops(cfg, b, s, m.d_ff_dense)
            else:
                total += _moe_flops(cfg, b, s, compiled)
    elif fam == "audio":
        enc_s = cfg.encoder_seq if kind != "decode" else 0
        if enc_s:
            for _ in range(cfg.n_encoder_layers):
                total += _proj_flops(cfg, b, enc_s)
                total += _attn_flops(cfg, b, enc_s, enc_s, causal=False, compiled=compiled)
                total += _mlp_flops(cfg, b, enc_s)
        for _ in range(cfg.n_layers):
            total += _proj_flops(cfg, b, s)
            total += _attn_flops(cfg, b, s, kv, compiled=compiled)
            total += _proj_flops(cfg, b, s)                 # cross proj
            total += _attn_flops(cfg, b, s, cfg.encoder_seq, causal=False, compiled=compiled)
            total += _mlp_flops(cfg, b, s)
    elif fam == "ssm":
        x = cfg.xlstm
        n_super = cfg.n_layers // x.slstm_every
        total += n_super * ((x.slstm_every - 1) * _mlstm_flops(cfg, b, s)
                            + _slstm_flops(cfg, b, s))
    elif fam == "hybrid":
        k = cfg.shared_attn_every
        n_attn = -(-cfg.n_layers // k)
        total += cfg.n_layers * _ssd_flops(cfg, b, s)
        total += n_attn * (_proj_flops(cfg, b, s)
                           + _attn_flops(cfg, b, s, kv, compiled=compiled)
                           + _mlp_flops(cfg, b, s))

    if kind == "train":
        mult = 3.0                                           # fwd + bwd
        if compiled and cfg.remat_policy == "full":
            mult = 4.0                                       # + recompute fwd
        total *= mult
    return float(total)


def model_flops_6nd(cfg: ModelConfig, shape_name: str) -> float:
    """The brief's MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE); D=tokens
    processed by the step (decode: one token per sequence)."""
    from repro.models.lm import count_params
    sh = SHAPES[shape_name]
    tokens = sh.global_batch * (1 if sh.kind == "decode" else sh.seq_len)
    n = count_params(cfg, active_only=True)
    mult = 6 if sh.kind == "train" else 2
    return float(mult * n * tokens)


def analytic_hbm_bytes(cfg: ModelConfig, shape_name: str,
                       n_chips: int) -> float:
    """Per-chip-summed HBM traffic model (bytes, whole step, all chips).

    train: params read 2x (fwd+bwd) + grads/opt state r/w (per accum: weights
    re-read) ; activations r/w ~ 2 passes of the residual stream per layer.
    decode: params + full cache read once per token; prefill: params once +
    activations.
    """
    from repro.models.lm import count_params
    sh = SHAPES[shape_name]
    n = count_params(cfg)
    pbytes = {"float32": 4, "bfloat16": 2}[cfg.param_dtype] * n
    act_unit = sh.global_batch * sh.seq_len * cfg.d_model * 2
    if sh.kind == "train":
        accum = max(cfg.train_accum, 1)
        opt = 2 * {"float32": 4, "bfloat16": 2}[cfg.adam_dtype] * n
        passes = 3 if cfg.remat_policy == "none" else 4
        return float(pbytes * (passes * accum + 2) + opt * 2
                     + act_unit * 4 * cfg.n_layers)
    if sh.kind == "prefill":
        return float(pbytes + act_unit * 4 * cfg.n_layers)
    # decode
    cache = _cache_bytes(cfg, sh)
    return float(pbytes + cache + sh.global_batch * cfg.d_model * 2
                 * cfg.n_layers * 8)


def _cache_bytes(cfg, sh):
    b, s = sh.global_batch, sh.seq_len
    if cfg.family == "ssm":
        x = cfg.xlstm
        inner = int(x.proj_factor_m * cfg.d_model)
        dh = inner // cfg.n_heads
        n_m = cfg.n_layers - cfg.n_layers // x.slstm_every
        return b * (n_m * cfg.n_heads * (dh * dh + dh) * 4
                    + (cfg.n_layers // x.slstm_every) * 4 * cfg.d_model * 4)
    if cfg.family == "hybrid":
        ss = cfg.ssm
        di = ss.expand * cfg.d_model
        n_attn = -(-cfg.n_layers // cfg.shared_attn_every)
        return b * (cfg.n_layers * (di // ss.head_dim) * ss.head_dim
                    * ss.d_state * 4
                    + n_attn * s * cfg.n_kv_heads * cfg.head_dim_ * 2 * 2)
    if cfg.mla is not None:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        return b * s * cfg.n_layers * per_tok * 2
    layers = cfg.n_layers + (cfg.n_encoder_layers if cfg.family == "audio"
                             else 0)
    return b * s * layers * cfg.n_kv_heads * cfg.head_dim_ * 2 * 2


def roofline_terms(cfg, shape_name: str, n_chips: int,
                   collective_bytes: float,
                   flops: float | None = None,
                   hbm_bytes: float | None = None) -> Dict[str, float]:
    f = flops if flops is not None else analytic_flops(cfg, shape_name)
    by = hbm_bytes if hbm_bytes is not None else analytic_hbm_bytes(
        cfg, shape_name, n_chips)
    t_c = f / (n_chips * HW.peak_flops)
    t_m = by / (n_chips * HW.hbm_bw)
    t_n = collective_bytes / (n_chips * HW.ici_links * HW.ici_bw)
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])
    mf = model_flops_6nd(cfg, shape_name)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom[0], "bound_s": dom[1],
        "model_flops_6nd": mf, "flops": f, "hbm_bytes": by,
        "collective_bytes": collective_bytes,
        "useful_ratio": mf / f if f else 0.0,
        "roofline_fraction": (mf / (n_chips * HW.peak_flops)) / dom[1]
        if dom[1] else 0.0,
    }

"""p-value machinery in JAX: chi-square, normal, Poisson, Kolmogorov."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chi2_sf(x, k):
    """P[Chi2_k >= x] (regularized upper incomplete gamma)."""
    return jax.scipy.special.gammaincc(k / 2.0, x / 2.0)


def normal_sf(z):
    return jax.scipy.special.ndtr(-z)


def normal_p_two_sided(z):
    return 2.0 * jax.scipy.special.ndtr(-jnp.abs(z))


def poisson_sf(k, lam):
    """P[Poisson(lam) >= k] = gammainc(k, lam) (regularized lower)."""
    return jnp.where(k <= 0, 1.0, jax.scipy.special.gammainc(
        jnp.maximum(k, 1e-9), lam))


def poisson_midp_upper(k, lam):
    """Mid-p upper tail: P[X > k] + 0.5 P[X = k] — approximately uniform
    under H0 for discrete Poisson statistics (both tails then flag via the
    suspect rule)."""
    p_ge = poisson_sf(k, lam)
    p_ge1 = poisson_sf(k + 1.0, lam)
    return jnp.clip(p_ge - 0.5 * (p_ge - p_ge1), 1e-300, 1.0)


def kolmogorov_sf(lam):
    """Q(lam) = 2 sum_{j>=1} (-1)^{j-1} exp(-2 j^2 lam^2)."""
    j = jnp.arange(1, 101, dtype=jnp.float32)
    terms = jnp.power(-1.0, j - 1) * jnp.exp(-2.0 * j ** 2 * lam ** 2)
    return jnp.clip(2.0 * jnp.sum(terms), 0.0, 1.0)


def ks_pvalue(sorted_u):
    """One-sample KS against U(0,1). sorted_u: ascending float32[n]."""
    n = sorted_u.shape[0]
    i = jnp.arange(1, n + 1, dtype=jnp.float32)
    d_plus = jnp.max(i / n - sorted_u)
    d_minus = jnp.max(sorted_u - (i - 1) / n)
    d = jnp.maximum(d_plus, d_minus)
    lam = (jnp.sqrt(float(n)) + 0.12 + 0.11 / jnp.sqrt(float(n))) * d
    return kolmogorov_sf(lam)


def chi2_from_counts(counts, expected):
    """(stat, dof) with TestU01-style clamping of tiny expected bins."""
    expected = jnp.maximum(expected, 1e-9)
    stat = jnp.sum(jnp.square(counts - expected) / expected)
    return stat

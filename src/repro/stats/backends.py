"""Kernel backend registry: every statistical test family behind one
``bits -> (stat, p)`` signature, with a ``reference`` (pure-jnp,
``stats/tests.py``) and — where a hand-written Pallas kernel covers the
hot loop — an ``accelerated`` implementation (DESIGN.md §7).

The accelerated paths route the counting hot loops through the fused
Pallas kernels that previously sat unused:

  gap / poker / weight / serial2d / collision
      -> ``kernels/histogram`` (scatter-free fused bin-count; collision
         only below ``HIST_MAX_BINS`` urns — paper-sized collision
         entries keep the sort-based path, see ``collision_accel``)
  rank
      -> ``kernels/gf2_rank``  (bit-packed GF(2) elimination)

Families whose hot loop has no Pallas kernel (birthday, coupon, maxoft,
hamcorr) fall back to the reference implementation under the
``accelerated`` backend, so a battery-wide backend choice always
resolves. Both implementations of a family share the same probability
model and p-value machinery — parity to float32 tolerance is asserted in
``tests/test_backends.py`` for every registered family.

Backend names:

  ``reference``    today's pure-jnp kernels — the oracle
  ``accelerated``  Pallas kernels (``interpret="auto"``: compiled on real
                   TPU, interpreted on CPU so CI exercises the same code)
  ``auto``         resolves to ``accelerated`` on a TPU backend and
                   ``reference`` everywhere else
"""
from __future__ import annotations

import math
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gf2_rank.ops import rank32
from repro.kernels.histogram.ops import bincount
from repro.rng.generators import to_unit
from repro.stats import tests as T
from repro.stats.special import (chi2_from_counts, chi2_sf,
                                 poisson_midp_upper)

BACKENDS = ("auto", "reference", "accelerated")

# Densest urn space the fused bin-count will materialize: the histogram
# kernel compares a (CHUNK, K) tile per grid step, so K is VMEM-bounded.
# Collision jobs with more urns than this keep the sort-based reference
# path even under the accelerated backend (static Python branch — kbits
# is a battery parameter, not a traced value).
HIST_MAX_BINS = 1 << 16

_REGISTRY: Dict[str, Dict[str, Callable]] = {}


def register(kname: str, backend: str, fn: Callable) -> None:
    """Register ``fn`` as the ``backend`` implementation of test family
    ``kname``. Signature contract: ``fn(bits, **params) -> (stat, p)``."""
    if backend not in ("reference", "accelerated"):
        raise KeyError(f"backend must be reference|accelerated, "
                       f"got {backend!r}")
    _REGISTRY.setdefault(kname, {})[backend] = fn


def families() -> list:
    """Every registered test family name."""
    return sorted(_REGISTRY)


def accelerated_families() -> list:
    """Families with a real accelerated implementation (no fallback)."""
    return sorted(k for k, d in _REGISTRY.items() if "accelerated" in d)


def default_backend() -> str:
    """What ``auto`` means here: accelerated on real TPU hardware,
    reference under interpret/CPU (the Pallas interpreter would only
    slow a CPU battery down; parity tests opt in explicitly)."""
    return "accelerated" if jax.default_backend() == "tpu" else "reference"


def resolve(backend: str) -> str:
    """Map a user-facing backend name to a concrete one."""
    if backend not in BACKENDS:
        raise KeyError(f"unknown backend {backend!r}; known: {BACKENDS}")
    return default_backend() if backend == "auto" else backend


def get_kernel(kname: str, backend: str = "reference") -> Callable:
    """The family's implementation under ``backend`` (resolved). A family
    without an accelerated implementation falls back to its reference —
    a battery-wide backend choice must always produce a full job table."""
    impls = _REGISTRY[kname]
    b = resolve(backend)
    if b not in impls:
        b = "reference"
    return impls[b]


# ---------------------------------------------------------------------------
# accelerated implementations (counting hot loops on the Pallas kernels;
# probability models shared with the reference in stats/tests.py)


def gap_accel(bits, n=65536, beta=0.125, maxlen=20):
    """`gap` with the gap-length histogram on the fused bin-count."""
    u = to_unit(bits[:n])
    hit = u < beta
    idx = jnp.arange(n)
    last = jax.lax.cummax(jnp.where(hit, idx, -1))
    prev = jnp.concatenate([jnp.array([-1]), last[:-1]])
    gaps = jnp.where(hit, idx - prev - 1, -1)
    gapc = jnp.clip(gaps, -1, maxlen)
    bins = jnp.where(hit, gapc, maxlen + 1).astype(jnp.int32)
    counts = bincount(bins, maxlen + 2)[:maxlen + 1]
    n_hits = jnp.sum(counts)
    probs = np.array([beta * (1 - beta) ** i for i in range(maxlen)]
                     + [(1 - beta) ** maxlen], np.float32)
    stat = chi2_from_counts(counts, n_hits * probs)
    return stat, chi2_sf(stat, maxlen)


def poker_accel(bits, n=32768, d=8, hand=5):
    """`poker` with the distinct-count histogram on the fused bin-count."""
    digits = (bits[:n * hand] >> 29).astype(jnp.int32).reshape(n, hand)
    s = jnp.sort(digits, axis=1)
    distinct = 1 + jnp.sum(jnp.diff(s, axis=1) != 0, axis=1)
    distinct = jnp.maximum(distinct, 2)
    counts = bincount((distinct - 2).astype(jnp.int32), hand - 1)
    probs = T._stirling_probs(d, hand)
    probs = np.concatenate([[probs[0] + probs[1]], probs[2:]])
    stat = chi2_from_counts(counts, n * probs)
    return stat, chi2_sf(stat, hand - 2)


def weight_accel(bits, n=65536):
    """`weight` with the Hamming-weight histogram on the fused bin-count."""
    w = jax.lax.population_count(bits[:n]).astype(jnp.int32)
    lo, hi = 10, 22
    b = (jnp.clip(w, lo, hi) - lo).astype(jnp.int32)
    counts = bincount(b, hi - lo + 1)
    probs = []
    for k in range(lo, hi + 1):
        if k == lo:
            probs.append(sum(math.comb(32, j)
                             for j in range(0, lo + 1)) / 2 ** 32)
        elif k == hi:
            probs.append(sum(math.comb(32, j)
                             for j in range(hi, 33)) / 2 ** 32)
        else:
            probs.append(math.comb(32, k) / 2 ** 32)
    probs = np.array(probs, np.float32)
    stat = chi2_from_counts(counts, n * probs)
    return stat, chi2_sf(stat, hi - lo)


def serial2d_accel(bits, n=65536, d=64):
    """`serial2d` with the cell histogram on the fused bin-count."""
    dbits = int(d).bit_length() - 1
    assert (1 << dbits) == d, "d must be a power of two"
    u = bits[:2 * n]
    x = (u[0::2] >> (32 - dbits)).astype(jnp.int32)
    y = (u[1::2] >> (32 - dbits)).astype(jnp.int32)
    cell = (x * d + y).astype(jnp.int32)
    counts = bincount(cell, d * d)
    stat = chi2_from_counts(counts, jnp.full((d * d,), n / (d * d)))
    return stat, chi2_sf(stat, d * d - 1)


def collision_accel(bits, n=65536, kbits=24):
    """`collision` with urn occupancy on the fused bin-count: distinct
    urns = occupied bins, so the collision count needs no sort. Falls
    back to the sort-based reference when the urn space exceeds
    ``HIST_MAX_BINS`` (dense occupancy would not fit VMEM)."""
    k = 1 << kbits
    if k > HIST_MAX_BINS:
        return T.collision(bits, n=n, kbits=kbits)
    urns = (bits[:n] >> (32 - kbits)).astype(jnp.int32)
    occ = bincount(urns, k)
    distinct = jnp.sum(occ > 0).astype(jnp.float32)
    coll = n - distinct
    kf = float(k)
    mean = n - kf + kf * (1.0 - 1.0 / kf) ** n
    return coll, poisson_midp_upper(coll, max(mean, 1e-9))


def rank_accel(bits, n_mats=1024):
    """`rank` on the bit-packed Pallas GF(2) elimination kernel, with the
    4-bin rank histogram on the fused bin-count."""
    mats = bits[:n_mats * 32].reshape(n_mats, 32)
    r = rank32(mats)
    b = jnp.clip(r - 29, 0, 3).astype(jnp.int32)
    counts = bincount(b, 4)
    stat = chi2_from_counts(counts, n_mats * T._rank_probs(32))
    return stat, chi2_sf(stat, 3)


# ---------------------------------------------------------------------------
# registration: every family gets a reference; six get accelerated paths

for _k, _fn in T.KERNELS.items():
    register(_k, "reference", _fn)

for _k, _fn in {"gap": gap_accel, "poker": poker_accel,
                "weight": weight_accel, "serial2d": serial2d_accel,
                "collision": collision_accel, "rank": rank_accel}.items():
    register(_k, "accelerated", _fn)

"""The battery's statistical test kernels (TestU01 SmallCrush analogues).

Every kernel has the uniform job signature ``kernel(bits: uint32[N]) ->
(stat: f32, p: f32)`` with its parameters STATICALLY bound (as in TestU01,
where each battery entry is a fixed parameterization). This uniformity is
what lets the pool dispatch heterogeneous tests through one ``lax.switch``
(DESIGN.md §2 — the paper's "one job = one test" on SPMD hardware).

Kernels (classic references in parentheses):
  birthday   — birthday spacings (Marsaglia), Poisson tail
  collision  — balls-in-urns collisions, normal approx
  gap        — gap lengths vs geometric, chi2
  poker      — distinct digits per 5-hand (simplified poker), chi2
  coupon     — coupon collector segment lengths, chi2
  maxoft     — max-of-t ^t uniformity, KS
  weight     — Hamming-weight histogram vs Binomial(32, 1/2), chi2
  rank       — 32x32 GF(2) matrix rank distribution, chi2
             (pure-jnp twin of kernels/gf2_rank)
  hamcorr    — lag-1 correlation of word Hamming weights, normal
  serial2d   — overlapping-free 2D serial pairs, chi2
"""
from __future__ import annotations

import math
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.rng.generators import to_unit
from repro.stats.special import (chi2_from_counts, chi2_sf, ks_pvalue,
                                 normal_p_two_sided, poisson_midp_upper)


# ---------------------------------------------------------------------------

def birthday(bits, n=4096, tbits=30):
    """Birthday spacings: n birthdays in 2^tbits days; duplicate spacings
    ~ Poisson(n^3 / 4k). Parameterized so lambda = n^3/4k stays in the
    Poisson regime (lambda << n)."""
    days = (bits[:n] >> (32 - tbits)).astype(jnp.uint32)
    s = jnp.sort(days)
    spacings = jnp.sort(jnp.diff(s))
    dup = jnp.sum((jnp.diff(spacings) == 0)).astype(jnp.float32)
    lam = n ** 3 / (4.0 * (1 << tbits))
    return dup, poisson_midp_upper(dup, lam)


def collision(bits, n=65536, kbits=24):
    """n balls into 2^kbits urns; collision count ~ Poisson(mean) in the
    sparse regime n << k (upper-tail sf; both tails are flagged by the
    suspect rule, matching TestU01's convention)."""
    urns = (bits[:n] >> (32 - kbits)).astype(jnp.uint32)
    s = jnp.sort(urns)
    distinct = 1.0 + jnp.sum(jnp.diff(s) != 0).astype(jnp.float32)
    coll = n - distinct
    k = float(1 << kbits)
    mean = n - k + k * (1.0 - 1.0 / k) ** n
    return coll, poisson_midp_upper(coll, max(mean, 1e-9))


def gap(bits, n=65536, beta=0.125, maxlen=20):
    """Gaps between visits to [0, beta); chi2 vs geometric."""
    u = to_unit(bits[:n])
    hit = u < beta
    idx = jnp.arange(n)
    last = jax.lax.cummax(jnp.where(hit, idx, -1))
    prev = jnp.concatenate([jnp.array([-1]), last[:-1]])
    gaps = jnp.where(hit, idx - prev - 1, -1)
    gapc = jnp.clip(gaps, -1, maxlen)
    counts = jnp.bincount(jnp.where(hit, gapc, maxlen + 1), length=maxlen + 2
                          )[:maxlen + 1].astype(jnp.float32)
    n_hits = jnp.sum(counts)
    probs = np.array([beta * (1 - beta) ** i for i in range(maxlen)]
                     + [(1 - beta) ** maxlen], np.float32)
    stat = chi2_from_counts(counts, n_hits * probs)
    return stat, chi2_sf(stat, maxlen)


def _stirling_probs(d=8, hand=5):
    """P[r distinct among `hand` draws from d values]."""
    # Stirling numbers of the second kind S(hand, r)
    S = np.zeros((hand + 1, hand + 1))
    S[0, 0] = 1
    for nn in range(1, hand + 1):
        for rr in range(1, nn + 1):
            S[nn, rr] = rr * S[nn - 1, rr] + S[nn - 1, rr - 1]
    probs = []
    for r in range(1, hand + 1):
        perm = 1.0
        for j in range(r):
            perm *= (d - j)
        probs.append(S[hand, r] * perm / d ** hand)
    return np.array(probs, np.float32)


def poker(bits, n=32768, d=8, hand=5):
    """Distinct values per hand of 5 3-bit digits; chi2."""
    digits = (bits[:n * hand] >> 29).astype(jnp.int32).reshape(n, hand)
    s = jnp.sort(digits, axis=1)
    distinct = 1 + jnp.sum(jnp.diff(s, axis=1) != 0, axis=1)
    # merge the rare r<=2 bins (expected count ~1e-4*n) for chi2 validity
    distinct = jnp.maximum(distinct, 2)
    counts = jnp.bincount(distinct - 2, length=hand - 1).astype(jnp.float32)
    probs = _stirling_probs(d, hand)
    probs = np.concatenate([[probs[0] + probs[1]], probs[2:]])
    stat = chi2_from_counts(counts, n * probs)
    return stat, chi2_sf(stat, hand - 2)


def coupon(bits, n=65536, d=8, maxlen=30):
    """Coupon-collector segment lengths; chi2 vs exact distribution."""
    dbits = int(d).bit_length() - 1
    assert (1 << dbits) == d, "d must be a power of two"
    digits = (bits[:n] >> (32 - dbits)).astype(jnp.int32)

    def body(st, dig):
        mask, ln, hist = st
        mask = mask | (1 << dig)
        ln = ln + 1
        done = mask == (1 << d) - 1
        binp = jnp.clip(ln - d, 0, maxlen - 1)
        hist = jnp.where(done, hist.at[binp].add(1.0), hist)
        mask = jnp.where(done, 0, mask)
        ln = jnp.where(done, 0, ln)
        return (mask, ln, hist), None

    (_, _, hist), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
               jnp.zeros((maxlen,), jnp.float32)), digits)
    # P[segment length = d+j]: exact via inclusion-exclusion on "all seen"
    def p_all_seen(ln):
        tot = 0.0
        for i in range(d + 1):
            tot += (-1) ** i * math.comb(d, i) * ((d - i) / d) ** ln
        return tot
    probs = np.array(
        [p_all_seen(d + j) - p_all_seen(d + j - 1) for j in range(maxlen - 1)]
        + [1.0 - p_all_seen(d + maxlen - 2)], np.float32)
    n_seg = jnp.sum(hist)
    stat = chi2_from_counts(hist, n_seg * np.maximum(probs, 1e-12))
    return stat, chi2_sf(stat, maxlen - 1)


def maxoft(bits, n=16384, t=8):
    """x = max(u_1..u_t)^... : F(x) = x^t, so x^t ~ U(0,1); KS."""
    u = to_unit(bits[:n * t]).reshape(n, t)
    m = jnp.max(u, axis=1) ** t
    return jnp.max(m), ks_pvalue(jnp.sort(m))


def weight(bits, n=65536):
    """Hamming weights of words vs Binomial(32, 1/2); chi2 (10..22 + tails)."""
    w = jax.lax.population_count(bits[:n]).astype(jnp.int32)
    lo, hi = 10, 22
    b = jnp.clip(w, lo, hi) - lo
    counts = jnp.bincount(b, length=hi - lo + 1).astype(jnp.float32)
    probs = []
    for k in range(lo, hi + 1):
        if k == lo:
            probs.append(sum(math.comb(32, j) for j in range(0, lo + 1)) / 2 ** 32)
        elif k == hi:
            probs.append(sum(math.comb(32, j) for j in range(hi, 33)) / 2 ** 32)
        else:
            probs.append(math.comb(32, k) / 2 ** 32)
    probs = np.array(probs, np.float32)
    stat = chi2_from_counts(counts, n * probs)
    return stat, chi2_sf(stat, hi - lo)


def gf2_rank32(mats):
    """Bit-packed GF(2) rank of (M, 32) uint32 row-matrices (pure-jnp ref
    for kernels/gf2_rank)."""
    m = mats.shape[0]
    rows0 = mats
    used0 = jnp.zeros((m, 32), bool)
    rank0 = jnp.zeros((m,), jnp.int32)
    ridx = jnp.arange(32)

    def body(i, st):
        rows, used, rank = st
        col = ((rows >> (31 - i)) & 1) == 1               # (M, 32)
        cand = col & ~used
        has = cand.any(axis=1)
        piv = jnp.argmax(cand, axis=1)                    # first candidate
        pivrow = jnp.take_along_axis(rows, piv[:, None], 1)[:, 0]
        pivrow = jnp.where(has, pivrow, 0)
        apply = col & (ridx[None, :] != piv[:, None])
        rows = jnp.where(apply, rows ^ pivrow[:, None], rows)
        used = used | (jax.nn.one_hot(piv, 32, dtype=bool) & has[:, None])
        rank = rank + has.astype(jnp.int32)
        return rows, used, rank

    _, _, rank = jax.lax.fori_loop(0, 32, body, (rows0, used0, rank0))
    return rank


def _rank_probs(dim=32):
    """P[rank = dim - j] for random GF(2) dim x dim; bins j=0,1,2,>=3."""
    def p_rank(r):
        # prod_{i=0}^{r-1} (1-2^{i-dim})^2 / (1-2^{i-r}) ... standard formula
        p = 2.0 ** (-(dim - r) * (dim - r))
        for i in range(r):
            p *= (1 - 2.0 ** (i - dim)) ** 2 / (1 - 2.0 ** (i - r))
        return p
    full, m1, m2 = p_rank(dim), p_rank(dim - 1), p_rank(dim - 2)
    return np.array([max(1 - full - m1 - m2, 1e-12), m2, m1, full],
                    np.float32)


def rank(bits, n_mats=1024):
    """32x32 GF(2) matrix rank distribution; chi2 over {<=29, 30, 31, 32}."""
    mats = bits[:n_mats * 32].reshape(n_mats, 32)
    r = gf2_rank32(mats)
    b = jnp.clip(r - 29, 0, 3)
    counts = jnp.bincount(b, length=4).astype(jnp.float32)
    stat = chi2_from_counts(counts, n_mats * _rank_probs(32))
    return stat, chi2_sf(stat, 3)


def hamcorr(bits, n=65536):
    """Lag-1 correlation of word Hamming weights; normal."""
    w = jax.lax.population_count(bits[:n]).astype(jnp.float32) - 16.0
    z = jnp.sum(w[:-1] * w[1:]) / (8.0 * math.sqrt(n - 1))
    return z, normal_p_two_sided(z)


def serial2d(bits, n=65536, d=64):
    """Non-overlapping pairs into d x d cells; chi2."""
    dbits = int(d).bit_length() - 1
    assert (1 << dbits) == d, "d must be a power of two"
    u = bits[:2 * n]
    x = (u[0::2] >> (32 - dbits)).astype(jnp.int32)
    y = (u[1::2] >> (32 - dbits)).astype(jnp.int32)
    cell = x * d + y
    counts = jnp.bincount(cell, length=d * d).astype(jnp.float32)
    stat = chi2_from_counts(counts, jnp.full((d * d,), n / (d * d)))
    return stat, chi2_sf(stat, d * d - 1)


def pairstream(bits, n=32768, mode="corr"):
    """Inter-stream disjointness/correlation at a sub-stream seam.

    The block is TWO adjacent sub-streams of one generator laid end to
    end: ``bits[:n]`` is the tail of stream s, ``bits[n:2n]`` the head of
    stream s+1 (the campaign dispatches this kernel at the seam offsets
    from ``rng.generators.seam_offsets``). Under the null the halves are
    independent; a broken jump-ahead offset (overlapping or correlated
    sub-streams) is exactly what each mode is sensitive to:

      ``corr``     Pearson cross-correlation of the unit floats,
                   z ~ N(0,1) two-sided
      ``hamcorr``  cross-correlation of word Hamming weights (catches
                   bit-level coupling the float map would wash out)
      ``match``    same-index word equality count ~ Poisson(n / 2^32) —
                   any match at all is a near-certain duplication
      ``shift``    equality between h1's last k and h2's first k words,
                   k = 1..8 — a seam that is off by k (stream s+1
                   starting k words early) duplicates exactly that
                   window
    """
    a, b = bits[:n], bits[n:2 * n]
    if mode == "corr":
        ua = to_unit(a) - 0.5
        ub = to_unit(b) - 0.5
        z = jnp.sum(ua * ub) * 12.0 / math.sqrt(n)   # var(U(-.5,.5)) = 1/12
        return z, normal_p_two_sided(z)
    if mode == "hamcorr":
        wa = jax.lax.population_count(a).astype(jnp.float32) - 16.0
        wb = jax.lax.population_count(b).astype(jnp.float32) - 16.0
        z = jnp.sum(wa * wb) / (8.0 * math.sqrt(n))  # var(weight) = 8
        return z, normal_p_two_sided(z)
    if mode == "match":
        m = jnp.sum(a == b).astype(jnp.float32)
        return m, poisson_midp_upper(m, n / 2.0 ** 32)
    if mode == "shift":
        maxk = 8
        m = jnp.float32(0.0)
        for k in range(1, maxk + 1):
            m = m + jnp.sum(a[n - k:] == b[:k]).astype(jnp.float32)
        lam = sum(range(1, maxk + 1)) / 2.0 ** 32
        return m, poisson_midp_upper(m, lam)
    raise KeyError(f"unknown pairstream mode {mode!r}; "
                   "known: corr, hamcorr, match, shift")


KERNELS: Dict[str, Callable] = {
    "birthday": birthday, "collision": collision, "gap": gap,
    "poker": poker, "coupon": coupon, "maxoft": maxoft, "weight": weight,
    "rank": rank, "hamcorr": hamcorr, "serial2d": serial2d,
    "pairstream": pairstream,
}

# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""AdamW + schedules, implemented directly in JAX (no optax dependency).

Optimizer state is a pytree parallel to params (sharded identically — the
FSDP/TP sharding of a param applies to its moments, ZeRO-style). Moments may
be kept in bf16 for very large models (``cfg.adam_dtype`` — the 340B preset),
an 8-bit-Adam-class footprint trade documented in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 200
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_at(step, oc: OptConfig):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(oc.warmup_steps, 1))
    prog = jnp.clip((step - oc.warmup_steps)
                    / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = oc.min_lr_frac + (1 - oc.min_lr_frac) * cos
    return oc.lr * warm * frac


def init_opt_state(params, adam_dtype=jnp.float32) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, adam_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params, grads, opt_state, oc: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(step, oc)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay \
            * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_params = jax.tree_util.tree_unflatten(treedef, [x[0] for x in new])
    new_m = jax.tree_util.tree_unflatten(treedef, [x[1] for x in new])
    new_v = jax.tree_util.tree_unflatten(treedef, [x[2] for x in new])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

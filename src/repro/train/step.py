# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""Train-step builder: gradient accumulation + AdamW, pjit-ready.

``make_train_step(cfg, oc)`` returns ``train_step(state, batch)`` where
``state = {"params", "opt"}`` and ``batch = {"tokens", "labels"[, "frames"]}``
with the *global* batch leading dim. Accumulation (``cfg.train_accum``) runs
microbatches through a ``lax.scan`` so the per-device live activation set is
``global_batch / (dp * accum)`` sequences — the activation-memory knob used
by the large archs (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm import loss_fn
from repro.train.optim import OptConfig, adamw_update


def make_train_step(cfg, oc: OptConfig):
    accum = max(cfg.train_accum, 1)

    def compute_grads(params, batch):
        b = batch["tokens"].shape[0]
        eff = min(accum, b)
        while b % eff:
            eff -= 1
        if eff == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
            return loss, grads

        def reshape(x):
            return x.reshape((eff, x.shape[0] // eff) + x.shape[1:])

        micro = jax.tree_util.tree_map(reshape, batch)
        zero_g = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(carry, mb):
            loss_sum, gsum = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb, cfg)
            gsum = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (loss_sum + loss, gsum), None

        (loss_sum, gsum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero_g), micro)
        grads = jax.tree_util.tree_map(lambda g: g / eff, gsum)
        return loss_sum / eff, grads

    def train_step(state, batch):
        params = state["params"]
        loss, grads = compute_grads(params, batch)
        new_params, new_opt, metrics = adamw_update(params, grads,
                                                    state["opt"], oc)
        metrics = dict(metrics, loss=loss)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step

"""Checkpoint IO: msgpack pytrees (battery progress + train state)."""
from __future__ import annotations

import os
import tempfile
from typing import Any

import msgpack
import numpy as np

import jax


def _pack(obj):
    if isinstance(obj, (np.ndarray, np.generic)):
        a = np.asarray(obj)
        return {b"__nd__": True, b"d": a.tobytes(), b"t": a.dtype.str,
                b"s": list(a.shape)}
    if isinstance(obj, jax.Array):
        return _pack(np.asarray(obj))
    return obj


def _unpack(obj):
    if isinstance(obj, dict) and obj.get(b"__nd__"):
        return np.frombuffer(obj[b"d"], dtype=np.dtype(obj[b"t"])
                             ).reshape(obj[b"s"]).copy()
    return obj


def save(path: str, tree: Any) -> None:
    """Atomic write (tmp + rename) — a crash mid-save never corrupts the
    previous checkpoint (restartability discipline, DESIGN.md §5)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten(tree)
    payload = {"leaves": [_pack(x) for x in flat],
               "treedef": str(treedef)}
    blob = msgpack.packb(payload, default=_pack, use_bin_type=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
    with os.fdopen(fd, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)


def load_flat(path: str):
    """Returns the list of leaves (caller re-applies its own structure)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=True,
                                  strict_map_key=False)
    return [_unpack(x) for x in payload[b"leaves"]]


def save_dict(path: str, d: dict) -> None:
    save(path, d)


def load_into(path: str, template: Any):
    """Load leaves into the structure of `template`."""
    leaves = load_flat(path)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def exists(path: str) -> bool:
    return os.path.exists(path)

# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""Attention: GQA (w/ local windows, softcaps, qk-norm, bias) and MLA.

Two compute paths:
  * dense path — materializes (S, T) scores; used for short sequences and
    single-token decode.
  * chunked path — lax.scan over KV chunks with an online softmax
    ("flash-in-XLA"); used when kv_len exceeds ``CHUNK_THRESHOLD``. The Pallas
    kernel in ``repro.kernels.flash_attention`` is the TPU-hardware twin of
    this path (validated against the same oracle).

Decode caches:
  * GQA: k/v per layer, (B, S_max, K, dh).
  * MLA: shared latent c_kv (B, S_max, r) + rope key (B, S_max, dr) — the
    DeepSeek-V2 "absorbed" decode, cache is head-count independent.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.constrain import constrain, seq_axis
from repro.models.common import apply_rope, rope_angles, softcap
from repro.models.params import P

BLOCK_THRESHOLD = 1024 * 1024   # q_len*kv_len above this -> blocked path
KV_CHUNK = 1024
NEG_INF = -2.3819763e38  # ~min bf16; used additively for masks


def _q_chunk(s: int) -> int:
    """Adaptive q-chunk: 8-16 outer segments, floor 512."""
    return max(512, s // 16)


# ---------------------------------------------------------------------------
# specs

def spec_attention(cfg):
    d, h, k, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    spec = {
        "wq": P((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": P((d, k, dh), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, k, dh), ("embed", "kv_heads", "head_dim")),
        "wo": P((h, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = P((h, dh), ("heads", "head_dim"), init="zeros")
        spec["bk"] = P((k, dh), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = P((k, dh), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        spec["q_norm"] = P((dh,), ("head_dim",), init="zeros")
        spec["k_norm"] = P((dh,), ("head_dim",), init="zeros")
    return spec


def spec_mla(cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dq, dkv = m.q_lora_rank, m.kv_lora_rank
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    return {
        "w_dq": P((d, dq), ("embed", "q_lora")),
        "q_norm": P((dq,), ("q_lora",), init="zeros"),
        "w_uq": P((dq, h, dn + dr), ("q_lora", "heads", "head_dim")),
        "w_dkv": P((d, dkv), ("embed", "kv_lora")),
        "kv_norm": P((dkv,), ("kv_lora",), init="zeros"),
        "w_uk": P((dkv, h, dn), ("kv_lora", "heads", "head_dim")),
        "w_uv": P((dkv, h, dv), ("kv_lora", "heads", "head_dim")),
        "w_kr": P((d, dr), ("embed", "head_dim")),
        "wo": P((h, dv, d), ("heads", "head_dim", "embed")),
    }


# ---------------------------------------------------------------------------
# masking helpers

PAD_POS = 2 ** 30  # sentinel position for padded keys (always masked)


def _mask_bias(q_pos, k_pos, kind: str, window: int):
    """Additive mask bias (q, k). kind: causal | local | bidir."""
    if kind == "bidir":
        ok = (k_pos < PAD_POS)[None, :] & jnp.ones(
            (q_pos.shape[0], 1), bool)
        return jnp.where(ok, 0.0, NEG_INF)
    diff = q_pos[:, None] - k_pos[None, :]
    ok = diff >= 0
    if kind == "local":
        ok = ok & (diff < window)
    return jnp.where(ok, 0.0, NEG_INF)


def _rmsnorm_vec(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# core softmax-attention over grouped heads

def _attend_dense(q, k, v, bias, scale, cap):
    """q: (B,S,K,g,dh) k,v: (B,T,K,dh) bias: (S,T) -> (B,S,K,g,dh)."""
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cap)
    scores = scores + bias[None, None, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", w, v)


def _attend_chunked(q, k, v, q_pos, k_pos, kind, window, scale, cap):
    """Online-softmax scan over KV chunks. Shapes as in _attend_dense."""
    b, s, kh, g, dh = q.shape
    dv = v.shape[-1]
    t = k.shape[1]
    n_chunks = -(-t // KV_CHUNK)
    pad = n_chunks * KV_CHUNK - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=PAD_POS)
    kc = k.reshape(b, n_chunks, KV_CHUNK, kh, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, KV_CHUNK, kh, dv).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, KV_CHUNK)

    m0 = jnp.full((b, kh, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, s), jnp.float32)
    a0 = jnp.zeros((b, s, kh, g, dv), jnp.float32)

    def body(carry, chunk):
        m, l, acc = carry
        kj, vj, pj = chunk
        bias = _mask_bias(q_pos, pj, kind, window)              # (s, C)
        scores = jnp.einsum("bskgd,bckd->bkgsc", q, kj,
                            preferred_element_type=jnp.float32) * scale
        scores = softcap(scores, cap) + bias[None, None, None]
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
            "bkgsc,bckd->bskgd", p.astype(vj.dtype), vj).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-37).transpose(0, 3, 1, 2)[..., None]
    return out


def _attend_blocked(qg, k, v, q_pos, k_pos, kind, window, scale, cap):
    """Triangle block schedule: python loop over q chunks; per chunk an
    online-softmax scan over ONLY the kv chunks that can be unmasked
    (causal: lower-triangular bands; local: the window band; bidir: all).
    This is the XLA twin of the Pallas flash kernel — block-bounded memory
    and no fully-masked-block compute."""
    b, s, kh, g, dh = qg.shape
    t = k.shape[1]
    qc = _q_chunk(s)
    if s % qc:
        qc = s
    outs = []
    # each q-chunk is rematerialized in the backward so only ONE chunk's
    # inner-scan residuals are ever live (flash-style memory discipline;
    # the Pallas kernel's custom VJP is the hardware twin of this)
    chunk_fn = jax.checkpoint(
        lambda q_i, k_i, v_i, p_i, kp_i: _attend_chunked(
            q_i, k_i, v_i, p_i, kp_i, kind, window, scale, cap),
        static_argnums=())
    for i in range(s // qc):
        q_i = qg[:, i * qc:(i + 1) * qc]
        p_i = q_pos[i * qc:(i + 1) * qc]
        if kind == "bidir":
            lo, hi = 0, t
        else:
            hi = min(t, (i + 1) * qc)          # static causal upper bound
            lo = 0
            if kind == "local":
                lo = max(0, (i * qc - window + 1) // KV_CHUNK * KV_CHUNK)
        outs.append(chunk_fn(q_i, k[:, lo:hi], v[:, lo:hi], p_i,
                             k_pos[lo:hi]))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def sdpa(q, k, v, q_pos, k_pos, kind, window, scale, cap):
    """Dispatch dense vs blocked. q: (B,S,H,dq) k: (B,T,K,dq) v: (B,T,K,dv)."""
    b, s, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, dh)
    if s > 1 and s * k.shape[1] > BLOCK_THRESHOLD:
        out = _attend_blocked(qg, k, v, q_pos, k_pos, kind, window, scale, cap)
    else:
        bias = _mask_bias(q_pos, k_pos, kind, window)
        out = _attend_dense(qg, k, v, bias, scale, cap)
    return out.reshape(b, s, h, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA module

def _project_qkv(p, x, kv_x, cfg):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dke->bske", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dke->bske", src, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if "q_norm" in p:
        q = _rmsnorm_vec(q, p["q_norm"])
        k = _rmsnorm_vec(k, p["k_norm"])
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv", None)
    v = constrain(v, "batch", "seq", "kv", None)
    return q, k, v


def attention(p, x, cfg, *, kind="global", mode="causal", positions=None,
              kv_x=None, kv_positions=None, return_kv=False):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, kv_x, cfg)
    t = k.shape[1]
    q_pos = positions if positions is not None else jnp.arange(s)
    k_pos = kv_positions if kv_positions is not None else (
        q_pos if kv_x is None else jnp.arange(t))
    if cfg.rope and kv_x is None:
        cos, sin = rope_angles(q_pos, cfg.head_dim_, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    scale = cfg.query_scale or cfg.head_dim_ ** -0.5
    mask_kind = kind if mode == "causal" else "bidir"
    if kind == "local" and mode != "causal":
        mask_kind = "bidir"
    out = sdpa(q, k, v, q_pos, k_pos, mask_kind, cfg.local_window, scale,
               cfg.attn_softcap)
    out = constrain(out, "batch", "seq", "heads", None)
    y = constrain(jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype)),
                  "batch", seq_axis(), None)
    if return_kv:
        return y, (k, v)
    return y


def attention_decode(p, x, cache_k, cache_v, pos, cfg, *, kind="global"):
    """One-token decode. x: (B,1,D); cache: (B,S_max,K,dh); pos scalar int.

    Returns (y, new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, None, cfg)
    if cfg.rope:
        posv = jnp.full((1,), pos)
        cos, sin = rope_angles(posv, cfg.head_dim_, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    s_max = cache_k.shape[1]
    k_pos = jnp.arange(s_max)
    valid = k_pos <= pos
    if kind == "local":
        valid = valid & (k_pos > pos - cfg.local_window)
    scale = cfg.query_scale or cfg.head_dim_ ** -0.5
    kh = cache_k.shape[2]
    g = cfg.n_heads // kh
    qg = q.reshape(b, 1, kh, g, cfg.head_dim_)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, cache_k.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    scores = softcap(scores, cfg.attn_softcap)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, cache_v.astype(q.dtype))
    out = out.reshape(b, 1, cfg.n_heads, cfg.head_dim_)
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return y, cache_k, cache_v


def cross_attention_decode(p, x, cross_k, cross_v, cfg):
    """Decode-time cross attention against a fixed encoder cache."""
    b = x.shape[0]
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
    t = cross_k.shape[1]
    out = sdpa(q, cross_k.astype(q.dtype), cross_v.astype(q.dtype),
               jnp.zeros((1,), jnp.int32), jnp.arange(t), "bidir",
               cfg.local_window, cfg.head_dim_ ** -0.5, 0.0)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)

def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(x.dtype))
    cq = _rmsnorm_vec(cq, p["q_norm"])
    q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"].astype(x.dtype))
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_latent(p, x, cfg, positions):
    m = cfg.mla
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    c_kv = _rmsnorm_vec(c_kv, p["kv_norm"])
    k_rope = jnp.einsum("bsd,de->bse", x, p["w_kr"].astype(x.dtype))
    cos, sin = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention(p, x, cfg, *, positions=None, return_cache=False):
    """Train/prefill MLA: materializes per-head K/V from the latent."""
    m = cfg.mla
    b, s, _ = x.shape
    pos = positions if positions is not None else jnp.arange(s)
    q_nope, q_rope = _mla_q(p, x, cfg, pos)
    c_kv, k_rope = _mla_latent(p, x, cfg, pos)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"].astype(x.dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, cfg.n_heads, m.qk_rope_head_dim))],
        axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    out = sdpa(q, k, v, pos, pos, "causal", 0, scale, 0.0)
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    if return_cache:
        return y, (c_kv, k_rope)
    return y


def mla_decode(p, x, cache_ckv, cache_kr, pos, cfg):
    """Absorbed MLA decode: score/value computed in latent space.

    cache_ckv: (B, S_max, r), cache_kr: (B, S_max, dr). Cache grows by one.
    """
    m = cfg.mla
    b = x.shape[0]
    posv = jnp.full((1,), pos)
    q_nope, q_rope = _mla_q(p, x, cfg, posv)                     # (B,1,H,*)
    c_kv, k_rope = _mla_latent(p, x, cfg, posv)
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv.astype(cache_ckv.dtype), pos, axis=1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, k_rope.astype(cache_kr.dtype), pos, axis=1)
    # absorb W_uk into q: (B,1,H,dn) @ (r,H,dn) -> (B,1,H,r)
    q_lat = jnp.einsum("bshe,rhe->bshr", q_nope, p["w_uk"].astype(x.dtype))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, cache_ckv.astype(x.dtype),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshe,bte->bhst", q_rope, cache_kr.astype(x.dtype),
                           preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(cache_ckv.shape[1]) <= pos
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhst,btr->bshr", w, cache_ckv.astype(x.dtype))
    out = jnp.einsum("bshr,rhe->bshe", o_lat, p["w_uv"].astype(x.dtype))
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return y, cache_ckv, cache_kr

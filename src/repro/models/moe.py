# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""Mixture-of-Experts layer (GShard-style dense dispatch, EP over 'model').

Capacity-based top-k routing with one-hot dispatch/combine einsums — the
standard JAX MoE formulation (t5x/flaxformer): with experts sharded over the
'model' mesh axis and tokens over 'data', GSPMD lowers the dispatch einsums
into the all-to-all-class collectives the roofline tracks.

Supports DeepSeek-style shared experts (always-on) and a router aux loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.constrain import constrain
from repro.models.common import act_fn
from repro.models.params import P


def spec_moe(cfg):
    m = cfg.moe
    d, e, f = cfg.d_model, m.n_experts, m.d_ff_expert
    spec = {
        "router": P((d, e), ("embed", "experts"), scale=0.006),
        "w_in": P((e, d, f), ("experts", "embed", "mlp")),
        "w_out": P((e, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.gated_mlp:
        spec["w_gate"] = P((e, d, f), ("experts", "embed", "mlp"))
    if m.n_shared:
        fs = m.d_ff_shared * m.n_shared
        spec["shared"] = {
            "w_in": P((d, fs), ("embed", "mlp")),
            "w_out": P((fs, d), ("mlp", "embed")),
        }
        if cfg.gated_mlp:
            spec["shared"]["w_gate"] = P((d, fs), ("embed", "mlp"))
    return spec


MOE_GROUP = 2048   # tokens per routing group (bounds capacity; see below)


def moe(p, x, cfg):
    """x: (B, S, D) -> (y, aux_loss).

    Grouped scatter/gather dispatch. The naive GShard one-hot dispatch
    einsum costs O(T * E * C * d) with C ∝ T — *quadratic* in tokens (the
    baseline measured in EXPERIMENTS.md §Perf iter 1 spent >99% of MoE
    FLOPs there). Two changes:
      1. tokens are routed within fixed GROUPS of G=2048, so per-group
         capacity C = cf*G*k/E is constant (dispatch work linear in T);
      2. dispatch/combine are a scatter-add/gather by slot index instead of
         one-hot matmuls — data movement, not MXU work.
    Expert GEMMs keep the (E, n*C, d) x (E, d, f) form sharded over
    'experts' (EP), which GSPMD lowers to the all-to-all class collectives.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    g_sz = min(MOE_GROUP, t)
    while t % g_sz:
        g_sz //= 2
    n_g = t // g_sz
    capacity = max(int(m.capacity_factor * g_sz * m.top_k / m.n_experts), 4)

    xt = constrain(x.reshape(t, d), "batch", None)
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)        # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True),
                                        1e-9)
    # aux load-balance loss (Switch/GShard form)
    me = probs.mean(axis=0)
    onehot_k = jax.nn.one_hot(expert_idx, m.n_experts, dtype=jnp.float32)
    ce = onehot_k.sum(axis=(0, 1)) / (t * m.top_k)
    aux = m.n_experts * jnp.sum(me * ce)

    # position within (group, expert) capacity buffer
    grp_oh = onehot_k.reshape(n_g, g_sz * m.top_k, m.n_experts)
    pos = (jnp.cumsum(grp_oh, axis=1) - grp_oh)                  # (n,G*k,E)
    pos = (pos * grp_oh).sum(-1).reshape(t, m.top_k).astype(jnp.int32)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    # scatter tokens into (n_g, E*C, d) slots; gather back after experts
    grp = jnp.arange(t) // g_sz                                  # (T,)
    slot = expert_idx * capacity + jnp.minimum(pos, capacity - 1)  # (T, k)
    flat_slot = grp[:, None] * (m.n_experts * capacity) + slot   # (T, k)
    buf = jnp.zeros((n_g * m.n_experts * capacity, d), x.dtype)
    src = xt[:, None, :] * keep[..., None].astype(x.dtype)
    expert_in = buf.at[flat_slot.reshape(-1)].add(
        src.reshape(t * m.top_k, d), mode="drop")
    # placement mirrors distributed/sharding.py: big experts -> EP over
    # 'model' (all-to-all); small experts -> replicated weights, tokens stay
    # on their data shards (no expert collectives at all)
    big_experts = m.n_experts * d * m.d_ff_expert * 4 >= 512e6
    e_ax = "experts" if big_experts else None
    t_ax = None if big_experts else "batch"
    expert_in = constrain(
        expert_in.reshape(n_g, m.n_experts, capacity, d
                          ).transpose(1, 0, 2, 3).reshape(m.n_experts,
                                                          n_g * capacity, d),
        e_ax, t_ax, None)

    act = act_fn(cfg.act)
    h = jnp.einsum("ecd,edf->ecf", expert_in, p["w_in"].astype(x.dtype))
    if "w_gate" in p:
        g = jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    h = constrain(h, e_ax, t_ax, "mlp" if not big_experts else None)
    expert_out = constrain(
        jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(x.dtype)),
        e_ax, t_ax, None)
    out_flat = expert_out.reshape(m.n_experts, n_g, capacity, d).transpose(
        1, 0, 2, 3).reshape(n_g * m.n_experts * capacity, d)
    gathered = out_flat[flat_slot.reshape(-1)].reshape(t, m.top_k, d)
    y = jnp.einsum("tkd,tk->td", gathered, gate_vals.astype(x.dtype))
    y = constrain(y, "batch", None)

    if m.n_shared:
        sp = p["shared"]
        hs = jnp.einsum("td,df->tf", xt, sp["w_in"].astype(x.dtype))
        if "w_gate" in sp:
            gs = jnp.einsum("td,df->tf", xt, sp["w_gate"].astype(x.dtype))
            hs = act(gs) * hs
        else:
            hs = act(hs)
        y = y + jnp.einsum("tf,fd->td", hs, sp["w_out"].astype(x.dtype))

    return y.reshape(b, s, d), aux * m.router_aux_coef

# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""Parameter-spec machinery.

Every module declares its parameters once as a ``Spec`` tree of ``P`` entries
(shape + logical axes + initializer). From a spec we derive:
  * materialized params  (``init_from_spec``)
  * abstract params      (``shapes_from_spec`` — ShapeDtypeStructs, no alloc)
  * logical-axis tree    (``axes_from_spec`` — consumed by distributed/sharding)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"           # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Spec = Dict[str, Any]  # nested dict of P


def stack_spec(spec: Spec, n: int, axis_name: Optional[str] = "layers") -> Spec:
    """Prepend a stacking dim (for scan-over-layers weights)."""
    out = {}
    for k, v in spec.items():
        if isinstance(v, dict):
            out[k] = stack_spec(v, n, axis_name)
        else:
            out[k] = P((n,) + v.shape, (axis_name,) + v.axes, v.init, v.scale)
    return out


def _leaves(spec: Spec):
    return jax.tree_util.tree_leaves(spec, is_leaf=lambda x: isinstance(x, P))


def init_from_spec(spec: Spec, key: jax.Array, dtype=jnp.float32):
    leaves = _leaves(spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    it = iter(range(len(leaves)))

    def make(p: P):
        i = next(it)
        if p.init == "zeros":
            return jnp.zeros(p.shape, dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, dtype)
        return (jax.random.normal(keys[i], p.shape, dtype) * p.scale).astype(dtype)

    return jax.tree_util.tree_map(make, spec,
                                  is_leaf=lambda x: isinstance(x, P))


def shapes_from_spec(spec: Spec, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec,
        is_leaf=lambda x: isinstance(x, P))


def axes_from_spec(spec: Spec):
    return jax.tree_util.tree_map(lambda p: p.axes, spec,
                                  is_leaf=lambda x: isinstance(x, P))


def count_spec_params(spec: Spec) -> int:
    return int(sum(np.prod(p.shape) for p in _leaves(spec)))

# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""Model assembly for all assigned architectures.

Public surface:
  model_spec(cfg)                 -> param Spec tree (shapes + logical axes)
  init_params(cfg, key)           -> materialized params
  abstract_params(cfg)            -> ShapeDtypeStruct tree (dry-run, no alloc)
  forward(params, tokens, cfg, frames=None) -> logits (B, S, V_padded)
  loss_fn(params, batch, cfg)     -> scalar loss (CE + MoE aux)
  init_cache(cfg, batch, max_seq) -> decode cache pytree
  prefill(params, tokens, cfg)    -> (last_logits, cache)
  decode_step(params, cache, token, cfg) -> (logits, cache)
  count_params(cfg, active_only=False) -> int   (shape-only, no jax compute)

Layer stacking: weights carry a leading unit dim and are consumed by
``lax.scan`` (optionally nested scan-of-scan via ``cfg.scan_group`` for
hierarchical remat). Heterogeneous per-arch structure (gemma2 local/global
pairs, deepseek leading dense layer, xlstm superblocks, zamba2 shared-attn
groups) is expressed in the *unit* definition, keeping every scan uniform.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.constrain import constrain, seq_axis
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import apply_norm, norm_spec, sinusoid_pos
from repro.models.mlp import mlp, spec_mlp
from repro.models.params import (P, axes_from_spec, count_spec_params,
                                 init_from_spec, shapes_from_spec, stack_spec)

WHISPER_MAX_POS = 32768


# ---------------------------------------------------------------------------
# block specs

def _spec_attn_block(cfg, use_moe: bool, d_ff=None, use_mla=False):
    spec = {
        "pre_attn": norm_spec(cfg.d_model),
        "attn": attn_mod.spec_mla(cfg) if use_mla else attn_mod.spec_attention(cfg),
        "pre_mlp": norm_spec(cfg.d_model),
        "mlp": moe_mod.spec_moe(cfg) if use_moe else spec_mlp(cfg, d_ff),
    }
    if cfg.post_block_norm:
        spec["post_attn"] = norm_spec(cfg.d_model)
        spec["post_mlp"] = norm_spec(cfg.d_model)
    return spec


def _unit_structure(cfg):
    """Returns (n_units, unit_kinds) for the homogeneous scan over units."""
    pat = cfg.attn_pattern
    assert cfg.n_layers % len(pat) == 0
    return cfg.n_layers // len(pat), pat


def model_spec(cfg) -> Dict[str, Any]:
    d = cfg.d_model
    spec: Dict[str, Any] = {
        "embed": P((cfg.padded_vocab, d), ("vocab", "embed"), scale=0.02),
        "final_norm": norm_spec(d),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = P((d, cfg.padded_vocab), ("embed", "vocab"))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        n_units, pat = _unit_structure(cfg)
        unit = {k: _spec_attn_block(cfg, use_moe=False)
                for k in (pat if len(pat) > 1 else ("blk",))}
        spec["units"] = stack_spec(unit, n_units)
    elif fam == "moe":
        use_mla = cfg.mla is not None
        m = cfg.moe
        n_moe = cfg.n_layers - m.first_dense_layers
        if m.first_dense_layers:
            spec["head_blocks"] = stack_spec(
                _spec_attn_block(cfg, use_moe=False, d_ff=m.d_ff_dense,
                                 use_mla=use_mla), m.first_dense_layers)
        spec["units"] = stack_spec(
            {"blk": _spec_attn_block(cfg, use_moe=True, use_mla=use_mla)}, n_moe)
    elif fam == "audio":
        enc_block = {
            "pre_attn": norm_spec(d, "ln"),
            "attn": attn_mod.spec_attention(cfg),
            "pre_mlp": norm_spec(d, "ln"),
            "mlp": spec_mlp(cfg),
        }
        dec_block = {
            "pre_attn": norm_spec(d, "ln"),
            "attn": attn_mod.spec_attention(cfg),
            "pre_cross": norm_spec(d, "ln"),
            "cross": attn_mod.spec_attention(cfg),
            "pre_mlp": norm_spec(d, "ln"),
            "mlp": spec_mlp(cfg),
        }
        spec["encoder"] = stack_spec(enc_block, cfg.n_encoder_layers)
        spec["units"] = stack_spec(dec_block, cfg.n_layers)
        spec["enc_final_norm"] = norm_spec(d, "ln")
        spec["final_norm"] = norm_spec(d, "ln")
        spec["pos_embed"] = P((WHISPER_MAX_POS, d), (None, "embed"), scale=0.01)
    elif fam == "ssm":                                            # xlstm
        x = cfg.xlstm
        n_super = cfg.n_layers // x.slstm_every
        unit = {
            "mlstm": stack_spec(xlstm_mod.spec_mlstm(cfg), x.slstm_every - 1,
                                "inner_layers"),
            "slstm": xlstm_mod.spec_slstm(cfg),
        }
        spec["units"] = stack_spec(unit, n_super)
    elif fam == "hybrid":                                         # zamba2
        k = cfg.shared_attn_every
        n_full = cfg.n_layers // k                                # full groups
        tail = cfg.n_layers - n_full * k
        spec["shared_block"] = _spec_attn_block(cfg, use_moe=False)
        spec["units"] = stack_spec(
            {"mamba": stack_spec(ssm_mod.spec_mamba2(cfg), k, "inner_layers")},
            n_full)
        if tail:
            spec["tail"] = stack_spec(ssm_mod.spec_mamba2(cfg), tail)
    else:
        raise ValueError(fam)
    return spec


def init_params(cfg, key):
    return init_from_spec(model_spec(cfg), key, _pdtype(cfg))


def abstract_params(cfg):
    return shapes_from_spec(model_spec(cfg), _pdtype(cfg))


def param_axes(cfg):
    return axes_from_spec(model_spec(cfg))


def _pdtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def count_params(cfg, active_only: bool = False) -> int:
    spec = model_spec(cfg)
    if not active_only or cfg.moe is None:
        return count_spec_params(spec)
    total = 0
    leaves = jax.tree_util.tree_leaves(spec, is_leaf=lambda x: isinstance(x, P))
    frac = (cfg.moe.top_k / cfg.moe.n_experts) if cfg.moe.n_experts else 1.0
    for p in leaves:
        n = int(np.prod(p.shape))
        if "experts" in p.axes:
            n = int(n * frac)
        total += n
    return total


# ---------------------------------------------------------------------------
# block application (full sequence)

def _apply_attn_block(p, x, cfg, kind="global", mode="causal", use_mla=False,
                      use_moe=False, positions=None):
    x = constrain(x, "batch", seq_axis(), None)
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["pre_attn"], x, cfg)
    if use_mla:
        h = attn_mod.mla_attention(p["attn"], h, cfg, positions=positions)
    else:
        h = attn_mod.attention(p["attn"], h, cfg, kind=kind, mode=mode,
                               positions=positions)
    if "post_attn" in p:
        h = apply_norm(p["post_attn"], h, cfg)
    x = x + h
    h = apply_norm(p["pre_mlp"], x, cfg)
    if use_moe:
        h, aux = moe_mod.moe(p["mlp"], h, cfg)
    else:
        h = mlp(p["mlp"], h, cfg)
    if "post_mlp" in p:
        h = apply_norm(p["post_mlp"], h, cfg)
    return x + h, aux


def _remat(fn, cfg):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return jax.checkpoint(fn)


def _scan_units(body, x0, stacked, cfg):
    """Scan over units with remat; optional nested scan-of-scan grouping."""
    body_r = _remat(body, cfg)
    n_units = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    g = cfg.scan_group
    if g and n_units % g == 0 and n_units > g:
        outer = n_units // g
        regrouped = jax.tree_util.tree_map(
            lambda a: a.reshape((outer, g) + a.shape[1:]), stacked)

        def outer_body(carry, group_params):
            return jax.lax.scan(body_r, carry, group_params)

        return jax.lax.scan(_remat(outer_body, cfg), x0, regrouped)
    return jax.lax.scan(body_r, x0, stacked)


# ---------------------------------------------------------------------------
# forward (train / full-sequence)

def forward_hidden(params, tokens, cfg, frames=None):
    """tokens: (B, S) int32 -> (final-normed hidden (B, S, D), aux loss)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    emb = params["embed"]
    x = constrain(emb[tokens].astype(cdt), "batch", seq_axis(), None)
    if cfg.arch_id.startswith("gemma2"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)

    aux_total = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        n_units, pat = _unit_structure(cfg)
        kinds = pat if len(pat) > 1 else ("blk",)
        pat_kinds = pat if len(pat) > 1 else ("global",)

        def body(carry, unit_p):
            h, aux = carry
            for key, kind in zip(kinds, pat_kinds):
                h, a = _apply_attn_block(unit_p[key], h, cfg, kind=kind)
                aux = aux + a
            return (h, aux), None

        (x, aux_total), _ = _scan_units(body, (x, aux_total),
                                        params["units"], cfg)
    elif fam == "moe":
        use_mla = cfg.mla is not None
        if "head_blocks" in params:
            def dense_body(carry, blk):
                h, aux = carry
                h, a = _apply_attn_block(blk, h, cfg, use_mla=use_mla,
                                         use_moe=False)
                return (h, aux + a), None
            (x, aux_total), _ = jax.lax.scan(
                _remat(dense_body, cfg), (x, aux_total), params["head_blocks"])

        def body(carry, unit_p):
            h, aux = carry
            h, a = _apply_attn_block(unit_p["blk"], h, cfg, use_mla=use_mla,
                                     use_moe=True)
            return (h, aux + a), None

        (x, aux_total), _ = _scan_units(body, (x, aux_total),
                                        params["units"], cfg)
    elif fam == "audio":
        x, aux_total = _whisper_forward(params, x, tokens, frames, cfg)
    elif fam == "ssm":
        def body(carry, unit_p):
            h, aux = carry

            def inner(h2, mp):
                return h2 + xlstm_mod.mlstm(mp, h2, cfg), None

            h, _ = jax.lax.scan(_remat(inner, cfg), h, unit_p["mlstm"])
            h = h + xlstm_mod.slstm(unit_p["slstm"], h, cfg)
            return (h, aux), None

        (x, aux_total), _ = _scan_units(body, (x, aux_total),
                                        params["units"], cfg)
    elif fam == "hybrid":
        shared = params["shared_block"]

        def body(carry, unit_p):
            h, aux = carry
            h, a = _apply_attn_block(shared, h, cfg)

            def inner(h2, mp):
                return h2 + ssm_mod.mamba2(mp, h2, cfg), None

            h, _ = jax.lax.scan(_remat(inner, cfg), h, unit_p["mamba"])
            return (h, aux + a), None

        (x, aux_total), _ = _scan_units(body, (x, aux_total),
                                        params["units"], cfg)
        if "tail" in params:
            h, a = _apply_attn_block(shared, x, cfg)
            def inner(h2, mp):
                return h2 + ssm_mod.mamba2(mp, h2, cfg), None
            x, _ = jax.lax.scan(_remat(inner, cfg), h, params["tail"])
            aux_total = aux_total + a
    else:
        raise ValueError(fam)

    x = apply_norm(params["final_norm"], x, cfg)
    return x, aux_total


def forward(params, tokens, cfg, frames=None):
    """tokens -> (logits (B, S, V_padded), aux). Materializes full logits —
    use only for small configs/tests; the train path uses the fused chunked
    cross-entropy in ``loss_fn``."""
    x, aux = forward_hidden(params, tokens, cfg, frames=frames)
    return _lm_logits(params, x, cfg), aux


def _lm_logits(params, x, cfg):
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype)
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


def _whisper_forward(params, x, tokens, frames, cfg):
    cdt = x.dtype
    b, s, d = x.shape
    enc = frames.astype(cdt) + sinusoid_pos(frames.shape[1], d, cdt)[None]

    def enc_body(h, blk):
        a = apply_norm(blk["pre_attn"], h, cfg)
        h = h + attn_mod.attention(blk["attn"], a, cfg, mode="bidir")
        m = apply_norm(blk["pre_mlp"], h, cfg)
        return h + mlp(blk["mlp"], m, cfg), None

    enc, _ = jax.lax.scan(_remat(enc_body, cfg), enc, params["encoder"])
    enc = apply_norm(params["enc_final_norm"], enc, cfg)

    x = x + params["pos_embed"][:s].astype(cdt)[None]

    def dec_body(h, blk):
        a = apply_norm(blk["pre_attn"], h, cfg)
        h = h + attn_mod.attention(blk["attn"], a, cfg, mode="causal")
        c = apply_norm(blk["pre_cross"], h, cfg)
        h = h + attn_mod.attention(blk["cross"], c, cfg, mode="bidir", kv_x=enc)
        m = apply_norm(blk["pre_mlp"], h, cfg)
        return h + mlp(blk["mlp"], m, cfg), None

    x, _ = jax.lax.scan(_remat(dec_body, cfg), x, params["units"])
    return x, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# loss

LOSS_CHUNK = 512


def _ce_chunk(params, x_c, labels_c, cfg):
    """Cross-entropy for one sequence chunk, fused with the vocab projection.

    Never materializes (B, S, V): per chunk the live set is (B, chunk, V/TP)
    and the backward recomputes the chunk logits (jax.checkpoint at call
    site). Gold logits are extracted with a sharded mask-sum instead of
    take_along_axis (which would all-gather the vocab-sharded logits).
    """
    logits = constrain(_lm_logits(params, x_c, cfg).astype(jnp.float32),
                       "batch", "seq", "vocab")
    v = cfg.vocab_size
    if cfg.padded_vocab != v:
        neg = jnp.asarray(attn_mod.NEG_INF, jnp.float32)
        pad_mask = jnp.arange(cfg.padded_vocab) >= v
        logits = jnp.where(pad_mask[None, None, :], neg, logits)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(iota == labels_c[..., None], logits, 0.0),
                   axis=-1)
    return jnp.sum(lse - gold)


def loss_fn(params, batch, cfg):
    x, aux = forward_hidden(params, batch["tokens"], cfg,
                            frames=batch.get("frames"))
    labels = batch["labels"]
    b, s, d = x.shape
    ck = min(LOSS_CHUNK, s)
    if s % ck:
        ck = s
    n_chunks = s // ck
    chunk_fn = jax.checkpoint(lambda xc, lc: _ce_chunk(params, xc, lc, cfg))
    if n_chunks == 1:
        total = chunk_fn(x, labels)
    else:
        xs = (x.reshape(b, n_chunks, ck, d).transpose(1, 0, 2, 3),
              labels.reshape(b, n_chunks, ck).transpose(1, 0, 2))

        def body(acc, inp):
            xc, lc = inp
            return acc + chunk_fn(xc, lc), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    ce = total / (b * s)
    return ce + aux


# ---------------------------------------------------------------------------
# decode caches

def init_cache(cfg, batch: int, max_seq: int, dtype=None):
    """Build the decode cache pytree (zeros; prefill fills it)."""
    cdt = dtype or jnp.dtype(cfg.compute_dtype)
    fam = cfg.family
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    dh, kh = cfg.head_dim_, cfg.n_kv_heads
    if fam in ("dense", "vlm"):
        n_units, pat = _unit_structure(cfg)
        kinds = pat if len(pat) > 1 else ("blk",)
        cache["units"] = {
            k: {"k": jnp.zeros((n_units, batch, max_seq, kh, dh), cdt),
                "v": jnp.zeros((n_units, batch, max_seq, kh, dh), cdt)}
            for k in kinds}
    elif fam == "moe":
        m = cfg.moe
        n_moe = cfg.n_layers - m.first_dense_layers
        if cfg.mla is not None:
            r, dr = cfg.mla.kv_lora_rank, cfg.mla.qk_rope_head_dim
            if m.first_dense_layers:
                cache["head"] = {
                    "ckv": jnp.zeros((m.first_dense_layers, batch, max_seq, r), cdt),
                    "kr": jnp.zeros((m.first_dense_layers, batch, max_seq, dr), cdt)}
            cache["units"] = {
                "ckv": jnp.zeros((n_moe, batch, max_seq, r), cdt),
                "kr": jnp.zeros((n_moe, batch, max_seq, dr), cdt)}
        else:
            cache["units"] = {
                "k": jnp.zeros((n_moe, batch, max_seq, kh, dh), cdt),
                "v": jnp.zeros((n_moe, batch, max_seq, kh, dh), cdt)}
    elif fam == "audio":
        L = cfg.n_layers
        cache["units"] = {
            "k": jnp.zeros((L, batch, max_seq, kh, dh), cdt),
            "v": jnp.zeros((L, batch, max_seq, kh, dh), cdt)}
        cache["cross"] = {
            "k": jnp.zeros((L, batch, cfg.encoder_seq, kh, dh), cdt),
            "v": jnp.zeros((L, batch, cfg.encoder_seq, kh, dh), cdt)}
    elif fam == "ssm":
        x = cfg.xlstm
        n_super = cfg.n_layers // x.slstm_every
        inner, heads, mdh = xlstm_mod._mdims(cfg)
        nm = x.slstm_every - 1
        cache["mlstm"] = {
            "c": jnp.zeros((n_super, nm, batch, heads, mdh, mdh), jnp.float32),
            "n": jnp.zeros((n_super, nm, batch, heads, mdh), jnp.float32),
            "m": jnp.full((n_super, nm, batch, heads), -1e30, jnp.float32),
            "conv": jnp.zeros((n_super, nm, batch, x.conv_width - 1, inner), cdt)}
        d = cfg.d_model
        cache["slstm"] = {
            "c": jnp.zeros((n_super, batch, d), jnp.float32),
            "n": jnp.full((n_super, batch, d), 1e-6, jnp.float32),
            "h": jnp.zeros((n_super, batch, d), jnp.float32),
            "m": jnp.full((n_super, batch, d), -1e30, jnp.float32),
            "conv": jnp.zeros((n_super, batch, x.conv_width - 1, d), cdt)}
    elif fam == "hybrid":
        s = cfg.ssm
        d_inner, n_heads, conv_dim = ssm_mod._dims(cfg)
        k = cfg.shared_attn_every
        n_full = cfg.n_layers // k
        tail = cfg.n_layers - n_full * k
        n_attn = n_full + (1 if tail else 0)
        cache["attn"] = {
            "k": jnp.zeros((n_attn, batch, max_seq, kh, dh), cdt),
            "v": jnp.zeros((n_attn, batch, max_seq, kh, dh), cdt)}
        cache["mamba"] = {
            "conv": jnp.zeros((n_full, k, batch, s.d_conv - 1, conv_dim), cdt),
            "ssm": jnp.zeros((n_full, k, batch, n_heads, s.head_dim, s.d_state),
                             jnp.float32)}
        if tail:
            cache["tail"] = {
                "conv": jnp.zeros((tail, batch, s.d_conv - 1, conv_dim), cdt),
                "ssm": jnp.zeros((tail, batch, n_heads, s.head_dim, s.d_state),
                                 jnp.float32)}
    return cache

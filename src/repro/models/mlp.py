# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""Dense MLP (gated SwiGLU/GeGLU or plain squared-ReLU/GELU)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.distributed.constrain import constrain, seq_axis
from repro.models.common import act_fn
from repro.models.params import P


def spec_mlp(cfg, d_ff=None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    spec = {
        "w_in": P((d, f), ("embed", "mlp")),
        "w_out": P((f, d), ("mlp", "embed")),
    }
    if cfg.gated_mlp:
        spec["w_gate"] = P((d, f), ("embed", "mlp"))
    return spec


def mlp(p, x, cfg):
    act = act_fn(cfg.act)
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"].astype(x.dtype))
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    h = constrain(h, "batch", "seq", "mlp")
    return constrain(jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(x.dtype)),
                     "batch", seq_axis(), None)

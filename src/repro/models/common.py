# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""Shared model primitives: norms, activations, rope, dense helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import P


# ---------------------------------------------------------------------------
# norms

def rmsnorm(x, weight, eps=1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layernorm(x, weight, bias, eps=1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def norm_spec(d: int, kind: str = "rms"):
    if kind == "rms":
        return {"scale": P((d,), ("embed",), init="zeros")}
    return {"scale": P((d,), ("embed",), init="ones"),
            "bias": P((d,), ("embed",), init="zeros")}


def apply_norm(p, x, cfg, kind: str = "rms"):
    if "bias" in p:
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# activations

def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "gelu_plain":
        return lambda x: jax.nn.gelu(x, approximate=False)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# rotary embeddings

def rope_angles(positions, head_dim: int, theta: float):
    """positions: (..., S) int -> cos/sin (..., S, head_dim//2) fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D). cos/sin: (B, S, D//2) or (S, D//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    x32_1 = x1.astype(jnp.float32)
    x32_2 = x2.astype(jnp.float32)
    out1 = x32_1 * cos - x32_2 * sin
    out2 = x32_2 * cos + x32_1 * sin
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# sinusoidal absolute positions (whisper stub)

def sinusoid_pos(seq: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)

# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""Mamba-2 (SSD) block — chunkwise-parallel train/prefill + O(1) decode.

Faithful to the SSD formulation [arXiv:2405.21060]: scalar-per-head decay
``a_t = exp(dt_t * A_h)``; state ``h_t = a_t h_{t-1} + dt_t * B_t ⊗ x_t``;
output ``y_t = C_t · h_t + D_h x_t``, computed as (intra-chunk masked
attention-like matmul) + (inter-chunk state scan). TPU adaptation: the
chunk length is MXU-aligned (128) and the inter-chunk recurrence is a
``lax.scan`` whose carry is the (H, P, N) state — sized for VMEM residency.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.constrain import constrain
from repro.models.common import rmsnorm
from repro.models.params import P


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.d_state
    return d_inner, n_heads, conv_dim


def spec_mamba2(cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_dim = _dims(cfg)
    return {
        "pre_norm": P((d,), ("embed",), init="zeros"),
        # order: [z (gate), x, B, C, dt]
        "w_in": P((d, 2 * d_inner + 2 * s.d_state + n_heads), ("embed", "inner")),
        "conv_w": P((s.d_conv, conv_dim), (None, "inner"), scale=0.1),
        "conv_b": P((conv_dim,), ("inner",), init="zeros"),
        "a_log": P((n_heads,), ("ssm_heads",), init="ones"),
        "d_skip": P((n_heads,), ("ssm_heads",), init="ones"),
        "dt_bias": P((n_heads,), ("ssm_heads",), init="zeros"),
        "norm": P((d_inner,), ("inner",), init="zeros"),
        "w_out": P((d_inner, d), ("inner", "embed")),
    }


def _split_proj(p, u, cfg):
    s = cfg.ssm
    d_inner, n_heads, _ = _dims(cfg)
    u = rmsnorm(u, p["pre_norm"], cfg.norm_eps)
    zxbcdt = constrain(jnp.einsum("bld,de->ble", u, p["w_in"].astype(u.dtype)),
                       "batch", "seq", "inner")
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_inner + 2 * s.d_state]
    dt = zxbcdt[..., -n_heads:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv, width K. xbc: (B,L,C); state: (B,K-1,C) or None."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)
    out = sum(full[:, i:i + xbc.shape[1], :] * w[i].astype(xbc.dtype)
              for i in range(k))
    out = jax.nn.silu(out + b.astype(xbc.dtype))
    new_state = full[:, -(k - 1):, :]
    return out, new_state


def mamba2(p, u, cfg, conv_state=None, ssm_state=None):
    """Full-sequence SSD. u: (B, L, D) -> (B, L, D).

    When conv_state/ssm_state given, treats u as a continuation (prefill of a
    cache) and also returns final states.
    """
    s = cfg.ssm
    d_inner, n_heads, _ = _dims(cfg)
    b, l, _ = u.shape
    q = min(s.chunk, l)
    while l % q:
        q //= 2
    nc = l // q

    z, xbc, dt = _split_proj(p, u, cfg)
    xbc, final_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    x = xbc[..., :d_inner]
    bmat = xbc[..., d_inner:d_inner + s.d_state]                 # (B,L,N)
    cmat = xbc[..., d_inner + s.d_state:]                        # (B,L,N)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # (B,L,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                 # (H,)
    log_decay = dt * a                                            # (B,L,H) <= 0

    xh = x.reshape(b, nc, q, n_heads, s.head_dim)
    bc = bmat.reshape(b, nc, q, s.d_state)
    cc = cmat.reshape(b, nc, q, s.d_state)
    dtc = dt.reshape(b, nc, q, n_heads)
    ldc = log_decay.reshape(b, nc, q, n_heads)
    cums = jnp.cumsum(ldc, axis=2)                                # (B,nc,Q,H)

    # intra-chunk: M[t,s] = (C_t·B_s) exp(cum_t - cum_s) dt_s, causal
    cb = jnp.einsum("bnts,bnqs->bntq", cc, bc,
                    preferred_element_type=jnp.float32)           # (B,nc,Q,Q) t,q=src
    delta = cums[:, :, :, None, :] - cums[:, :, None, :, :]       # (B,nc,t,s,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    m = jnp.where(causal[None, None, :, :, None],
                  jnp.exp(delta), 0.0) * cb[..., None] * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bntsh,bnshp->bnthp", m, xh.astype(jnp.float32))

    # chunk-final states: S_k = sum_s exp(cum_Q - cum_s) dt_s B_s x_s
    w_state = jnp.exp(cums[:, :, -1:, :] - cums) * dtc            # (B,nc,Q,H)
    s_chunk = jnp.einsum("bnqh,bnqs,bnqhp->bnhps", w_state,
                         bc.astype(jnp.float32), xh.astype(jnp.float32))

    # inter-chunk scan
    chunk_decay = jnp.exp(cums[:, :, -1, :])                      # (B,nc,H)
    h0 = (jnp.zeros((b, n_heads, s.head_dim, s.d_state), jnp.float32)
          if ssm_state is None else ssm_state.astype(jnp.float32))

    def body(h, inp):
        dec, s_k = inp                                            # (B,H), (B,H,P,N)
        h_next = h * dec[:, :, None, None] + s_k
        return h_next, h

    (h_final, h_prevs) = jax.lax.scan(
        body, h0, (chunk_decay.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                    # (B,nc,H,P,N)

    y_inter = jnp.einsum("bnqs,bnhps,bnqh->bnqhp", cc.astype(jnp.float32),
                         h_prevs, jnp.exp(cums))
    y = (y_intra + y_inter).reshape(b, l, n_heads, s.head_dim)
    y = y + xh.reshape(b, l, n_heads, s.head_dim).astype(jnp.float32) \
        * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, l, d_inner).astype(u.dtype)

    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    y = constrain(y, "batch", "seq", "inner")
    out = constrain(jnp.einsum("ble,ed->bld", y, p["w_out"].astype(u.dtype)),
                    "batch", "seq", None)
    if conv_state is not None or ssm_state is not None:
        return out, final_conv, h_final
    return out


def mamba2_decode(p, u, conv_state, ssm_state, cfg):
    """One-step decode. u: (B,1,D); conv_state: (B,K-1,C); ssm_state: (B,H,P,N)."""
    s = cfg.ssm
    d_inner, n_heads, _ = _dims(cfg)
    b = u.shape[0]
    z, xbc, dt = _split_proj(p, u, cfg)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    x = xbc[:, 0, :d_inner]
    bvec = xbc[:, 0, d_inner:d_inner + s.d_state]
    cvec = xbc[:, 0, d_inner + s.d_state:]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a)                                         # (B,H)
    xh = x.reshape(b, n_heads, s.head_dim).astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, bvec.astype(jnp.float32))
    ssm_state = ssm_state * dec[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, cvec.astype(jnp.float32))
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, p["w_out"].astype(u.dtype))
    return out, conv_state, ssm_state

# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""Prefill + single-token decode for every architecture family.

``prefill(params, tokens, cfg, max_seq)`` runs the full-sequence forward
while building the decode cache (KV / MLA-latent / SSM states).
``decode_step(params, cache, token, cfg)`` consumes and returns the cache —
this is what ``serve_step`` lowers in the dry-run for decode shapes.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import apply_norm, sinusoid_pos
from repro.models.lm import (_lm_logits, _unit_structure, init_cache)
from repro.models.mlp import mlp


def _pad_seq(x, max_seq):
    if x.shape[1] == max_seq:
        return x
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, max_seq - x.shape[1])
    return jnp.pad(x, pad)


def _embed(params, tokens, cfg):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    if cfg.arch_id.startswith("gemma2"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    return x


def _block_prefill(p, x, cfg, kind, max_seq, use_mla=False, use_moe=False):
    h = apply_norm(p["pre_attn"], x, cfg)
    if use_mla:
        h, (ckv, kr) = attn_mod.mla_attention(p["attn"], h, cfg,
                                              return_cache=True)
        kv = {"ckv": _pad_seq(ckv, max_seq), "kr": _pad_seq(kr, max_seq)}
    else:
        h, (k, v) = attn_mod.attention(p["attn"], h, cfg, kind=kind,
                                       return_kv=True)
        kv = {"k": _pad_seq(k, max_seq), "v": _pad_seq(v, max_seq)}
    if "post_attn" in p:
        h = apply_norm(p["post_attn"], h, cfg)
    x = x + h
    h = apply_norm(p["pre_mlp"], x, cfg)
    if use_moe:
        h, _ = moe_mod.moe(p["mlp"], h, cfg)
    else:
        h = mlp(p["mlp"], h, cfg)
    if "post_mlp" in p:
        h = apply_norm(p["post_mlp"], h, cfg)
    return x + h, kv


def _block_decode(p, x, kv, pos, cfg, kind, use_mla=False, use_moe=False):
    h = apply_norm(p["pre_attn"], x, cfg)
    if use_mla:
        h, ckv, kr = attn_mod.mla_decode(p["attn"], h, kv["ckv"], kv["kr"],
                                         pos, cfg)
        kv = {"ckv": ckv, "kr": kr}
    else:
        h, ck, cv = attn_mod.attention_decode(p["attn"], h, kv["k"], kv["v"],
                                              pos, cfg, kind=kind)
        kv = {"k": ck, "v": cv}
    if "post_attn" in p:
        h = apply_norm(p["post_attn"], h, cfg)
    x = x + h
    h = apply_norm(p["pre_mlp"], x, cfg)
    if use_moe:
        h, _ = moe_mod.moe(p["mlp"], h, cfg)
    else:
        h = mlp(p["mlp"], h, cfg)
    if "post_mlp" in p:
        h = apply_norm(p["post_mlp"], h, cfg)
    return x + h, kv


# ---------------------------------------------------------------------------
# prefill

def prefill(params, tokens, cfg, max_seq=None, frames=None):
    """Returns (last_logits (B, V_padded), cache)."""
    b, s = tokens.shape
    max_seq = max_seq or s
    x = _embed(params, tokens, cfg)
    fam = cfg.family
    cache: Dict[str, Any] = {"pos": jnp.asarray(s, jnp.int32)}

    if fam in ("dense", "vlm"):
        n_units, pat = _unit_structure(cfg)
        kinds = pat if len(pat) > 1 else ("blk",)
        pat_kinds = pat if len(pat) > 1 else ("global",)

        def body(h, unit_p):
            ys = {}
            for key, kind in zip(kinds, pat_kinds):
                h, kv = _block_prefill(unit_p[key], h, cfg, kind, max_seq)
                ys[key] = kv
            return h, ys

        x, units_cache = jax.lax.scan(body, x, params["units"])
        cache["units"] = units_cache
    elif fam == "moe":
        use_mla = cfg.mla is not None
        if "head_blocks" in params:
            def hbody(h, blk):
                h, kv = _block_prefill(blk, h, cfg, "global", max_seq,
                                       use_mla=use_mla, use_moe=False)
                return h, kv
            x, head_cache = jax.lax.scan(hbody, x, params["head_blocks"])
            cache["head"] = head_cache

        def body(h, unit_p):
            h, kv = _block_prefill(unit_p["blk"], h, cfg, "global", max_seq,
                                   use_mla=use_mla, use_moe=True)
            return h, kv

        x, units_cache = jax.lax.scan(body, x, params["units"])
        cache["units"] = units_cache
    elif fam == "audio":
        x, cache = _whisper_prefill(params, x, tokens, frames, cfg, max_seq,
                                    cache)
    elif fam == "ssm":
        def body(h, unit_p):
            def inner(h2, mp):
                y, st = xlstm_mod.mlstm(mp, h2, cfg, return_state=True)
                return h2 + y, st
            h, m_states = jax.lax.scan(inner, h, unit_p["mlstm"])
            y, s_state = xlstm_mod.slstm(unit_p["slstm"], h, cfg,
                                         return_state=True)
            return h + y, {"mlstm": m_states, "slstm": s_state}

        x, states = jax.lax.scan(body, x, params["units"])
        cache["mlstm"] = states["mlstm"]
        cache["slstm"] = states["slstm"]
    elif fam == "hybrid":
        shared = params["shared_block"]
        s_cfg = cfg.ssm
        d_inner, n_heads, conv_dim = ssm_mod._dims(cfg)
        attn_caches = []

        def m_zero():
            return (jnp.zeros((b, s_cfg.d_conv - 1, conv_dim), x.dtype),
                    jnp.zeros((b, n_heads, s_cfg.head_dim, s_cfg.d_state),
                              jnp.float32))

        def body(h, unit_p):
            h, kv = _block_prefill(shared, h, cfg, "global", max_seq)

            def inner(h2, mp):
                cs, ss = m_zero()
                y, conv_f, ssm_f = ssm_mod.mamba2(mp, h2, cfg, cs, ss)
                return h2 + y, {"conv": conv_f, "ssm": ssm_f}

            h, m_states = jax.lax.scan(inner, h, unit_p["mamba"])
            return h, (kv, m_states)

        x, (attn_kv, mamba_states) = jax.lax.scan(body, x, params["units"])
        cache["mamba"] = mamba_states
        if "tail" in params:
            h, kv_tail = _block_prefill(shared, x, cfg, "global", max_seq)

            def inner(h2, mp):
                cs, ss = m_zero()
                y, conv_f, ssm_f = ssm_mod.mamba2(mp, h2, cfg, cs, ss)
                return h2 + y, {"conv": conv_f, "ssm": ssm_f}

            x, tail_states = jax.lax.scan(inner, h, params["tail"])
            cache["tail"] = tail_states
            attn_k = jnp.concatenate([attn_kv["k"], kv_tail["k"][None]], 0)
            attn_v = jnp.concatenate([attn_kv["v"], kv_tail["v"][None]], 0)
        else:
            attn_k, attn_v = attn_kv["k"], attn_kv["v"]
        cache["attn"] = {"k": attn_k, "v": attn_v}
    else:
        raise ValueError(fam)

    xl = apply_norm(params["final_norm"], x[:, -1:], cfg)
    logits = _lm_logits(params, xl, cfg)[:, 0]
    return logits, cache


def _whisper_prefill(params, x, tokens, frames, cfg, max_seq, cache):
    cdt = x.dtype
    b, s = tokens.shape
    d = cfg.d_model
    enc = frames.astype(cdt) + sinusoid_pos(frames.shape[1], d, cdt)[None]

    def enc_body(h, blk):
        a = apply_norm(blk["pre_attn"], h, cfg)
        h = h + attn_mod.attention(blk["attn"], a, cfg, mode="bidir")
        m = apply_norm(blk["pre_mlp"], h, cfg)
        return h + mlp(blk["mlp"], m, cfg), None

    enc, _ = jax.lax.scan(enc_body, enc, params["encoder"])
    enc = apply_norm(params["enc_final_norm"], enc, cfg)

    x = x + params["pos_embed"][:s].astype(cdt)[None]

    def dec_body(h, blk):
        a = apply_norm(blk["pre_attn"], h, cfg)
        a, (k, v) = attn_mod.attention(blk["attn"], a, cfg, mode="causal",
                                       return_kv=True)
        h = h + a
        c = apply_norm(blk["pre_cross"], h, cfg)
        c, (xk, xv) = attn_mod.attention(blk["cross"], c, cfg, mode="bidir",
                                         kv_x=enc, return_kv=True)
        h = h + c
        m = apply_norm(blk["pre_mlp"], h, cfg)
        return h + mlp(blk["mlp"], m, cfg), {
            "k": _pad_seq(k, max_seq), "v": _pad_seq(v, max_seq),
            "xk": xk, "xv": xv}

    x, ys = jax.lax.scan(dec_body, x, params["units"])
    cache["units"] = {"k": ys["k"], "v": ys["v"]}
    cache["cross"] = {"k": ys["xk"], "v": ys["xv"]}
    return x, cache


# ---------------------------------------------------------------------------
# decode

def decode_step(params, cache, token, cfg):
    """token: (B, 1) int32. Returns (logits (B, V_padded), new cache)."""
    pos = cache["pos"]
    x = _embed(params, token, cfg)
    fam = cfg.family
    new_cache: Dict[str, Any] = {"pos": pos + 1}

    if fam in ("dense", "vlm"):
        n_units, pat = _unit_structure(cfg)
        kinds = pat if len(pat) > 1 else ("blk",)
        pat_kinds = pat if len(pat) > 1 else ("global",)

        def body(h, inp):
            unit_p, unit_kv = inp
            ys = {}
            for key, kind in zip(kinds, pat_kinds):
                h, kv = _block_decode(unit_p[key], h, unit_kv[key], pos, cfg,
                                      kind)
                ys[key] = kv
            return h, ys

        x, units_cache = jax.lax.scan(body, x, (params["units"],
                                                cache["units"]))
        new_cache["units"] = units_cache
    elif fam == "moe":
        use_mla = cfg.mla is not None
        if "head_blocks" in params:
            def hbody(h, inp):
                blk, kv = inp
                h, kv = _block_decode(blk, h, kv, pos, cfg, "global",
                                      use_mla=use_mla)
                return h, kv
            x, head_cache = jax.lax.scan(hbody, x, (params["head_blocks"],
                                                    cache["head"]))
            new_cache["head"] = head_cache

        def body(h, inp):
            unit_p, kv = inp
            h, kv = _block_decode(unit_p["blk"], h, kv, pos, cfg, "global",
                                  use_mla=use_mla, use_moe=True)
            return h, kv

        x, units_cache = jax.lax.scan(body, x, (params["units"],
                                                cache["units"]))
        new_cache["units"] = units_cache
    elif fam == "audio":
        x = x + params["pos_embed"][pos][None, None].astype(x.dtype)

        def body(h, inp):
            blk, k, v, xk, xv = inp
            a = apply_norm(blk["pre_attn"], h, cfg)
            a, k2, v2 = attn_mod.attention_decode(blk["attn"], a, k, v, pos,
                                                  cfg)
            h = h + a
            c = apply_norm(blk["pre_cross"], h, cfg)
            h = h + attn_mod.cross_attention_decode(blk["cross"], c, xk, xv,
                                                    cfg)
            m = apply_norm(blk["pre_mlp"], h, cfg)
            return h + mlp(blk["mlp"], m, cfg), {"k": k2, "v": v2}

        x, ys = jax.lax.scan(body, x, (params["units"], cache["units"]["k"],
                                       cache["units"]["v"],
                                       cache["cross"]["k"],
                                       cache["cross"]["v"]))
        new_cache["units"] = ys
        new_cache["cross"] = cache["cross"]
    elif fam == "ssm":
        def body(h, inp):
            unit_p, m_st, s_st = inp

            def inner(h2, inp2):
                mp, st = inp2
                y, st2 = xlstm_mod.mlstm_decode(mp, h2, st, cfg)
                return h2 + y, st2

            h, m_new = jax.lax.scan(inner, h, (unit_p["mlstm"], m_st))
            y, s_new = xlstm_mod.slstm_decode(unit_p["slstm"], h, s_st, cfg)
            return h + y, {"m": m_new, "s": s_new}

        x, states = jax.lax.scan(body, x, (params["units"], cache["mlstm"],
                                           cache["slstm"]))
        new_cache["mlstm"] = states["m"]
        new_cache["slstm"] = states["s"]
    elif fam == "hybrid":
        shared = params["shared_block"]
        n_full = cache["mamba"]["ssm"].shape[0]
        ak, av = cache["attn"]["k"], cache["attn"]["v"]

        def body(h, inp):
            unit_p, kv, m_st = inp
            h, kv2 = _block_decode(shared, h, kv, pos, cfg, "global")

            def inner(h2, inp2):
                mp, st = inp2
                y, conv2, ssm2 = ssm_mod.mamba2_decode(mp, h2, st["conv"],
                                                       st["ssm"], cfg)
                return h2 + y, {"conv": conv2, "ssm": ssm2}

            h, m_new = jax.lax.scan(inner, h, (unit_p["mamba"], m_st))
            return h, (kv2, m_new)

        x, (kv_new, m_new) = jax.lax.scan(
            body, x, (params["units"],
                      {"k": ak[:n_full], "v": av[:n_full]}, cache["mamba"]))
        new_cache["mamba"] = m_new
        if "tail" in params:
            h, kv_tail = _block_decode(
                shared, x, {"k": ak[n_full], "v": av[n_full]}, pos, cfg,
                "global")

            def inner(h2, inp2):
                mp, st = inp2
                y, conv2, ssm2 = ssm_mod.mamba2_decode(mp, h2, st["conv"],
                                                       st["ssm"], cfg)
                return h2 + y, {"conv": conv2, "ssm": ssm2}

            x, tail_new = jax.lax.scan(inner, h, (params["tail"],
                                                  cache["tail"]))
            new_cache["tail"] = tail_new
            new_cache["attn"] = {
                "k": jnp.concatenate([kv_new["k"], kv_tail["k"][None]], 0),
                "v": jnp.concatenate([kv_new["v"], kv_tail["v"][None]], 0)}
        else:
            new_cache["attn"] = kv_new
    else:
        raise ValueError(fam)

    xl = apply_norm(params["final_norm"], x, cfg)
    logits = _lm_logits(params, xl, cfg)[:, 0]
    return logits, new_cache

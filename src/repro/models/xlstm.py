# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, true recurrence via lax.scan).

mLSTM cell:  C_t = f_t C_{t-1} + i_t v_t k_t^T ;  n_t = f_t n_{t-1} + i_t k_t
             h_t = (C_t q_t) / max(|n_t . q_t|, 1)
with exponential input gate and sigmoid forget gate, computed in log space.
Chunkwise-parallel form mirrors SSD (see ssm.py): intra-chunk masked
attention matrix + inter-chunk (dk, dv) state scan, with the paper's
running-max stabilizer carried exactly through the chunk scan
(C_true = c_hat * exp(M)); the recurrent decode path uses the same
stabilizer per step, so chunked and recurrent paths agree to fp32.

sLSTM: 4-gate scalar cell with per-head block-diagonal recurrent matrices and
exponential-gate stabilizer m_t, scanned over time (inherently sequential —
the paper's reason mLSTM dominates the 7:1 ratio).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.constrain import constrain
from repro.models.common import rmsnorm
from repro.models.params import P


# ---------------------------------------------------------------------------
# mLSTM

def _mdims(cfg):
    x = cfg.xlstm
    inner = int(x.proj_factor_m * cfg.d_model)
    heads = cfg.n_heads
    dh = inner // heads
    return inner, heads, dh


def spec_mlstm(cfg):
    x = cfg.xlstm
    d = cfg.d_model
    inner, heads, dh = _mdims(cfg)
    return {
        "norm": P((d,), ("embed",), init="zeros"),
        "w_up": P((d, inner), ("embed", "inner")),
        "w_gate": P((d, inner), ("embed", "inner")),
        "conv_w": P((x.conv_width, inner), (None, "inner"), scale=0.1),
        "conv_b": P((inner,), ("inner",), init="zeros"),
        # block-diagonal per-head projections (xLSTM paper's BlockDiagonal)
        "wq": P((heads, dh, dh), ("heads", None, "head_dim")),
        "wk": P((heads, dh, dh), ("heads", None, "head_dim")),
        "wv": P((heads, dh, dh), ("heads", None, "head_dim")),
        "w_if": P((inner, 2 * heads), ("inner", None), scale=0.01),
        "b_if": P((2 * heads,), (None,), init="zeros"),
        "out_norm": P((inner,), ("inner",), init="zeros"),
        "w_down": P((inner, d), ("inner", "embed")),
    }


def _conv_causal(x, w, b, state=None):
    k = w.shape[0]
    pad = (jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    full = jnp.concatenate([pad, x], axis=1)
    out = sum(full[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(k))
    return jax.nn.silu(out + b.astype(x.dtype)), full[:, -(k - 1):, :]


def mlstm(p, u, cfg, return_state: bool = False):
    """u: (B, L, D). Chunkwise-parallel mLSTM block (pre-norm, residual added
    by the caller) with an exact carried running-max stabilizer: the scan
    carry is (c_hat, n_hat, M) with C_true = c_hat * exp(M)."""
    xc = cfg.xlstm
    inner, heads, dh = _mdims(cfg)
    b, l, d = u.shape
    q_len = min(xc.chunk, l)
    while l % q_len:
        q_len //= 2
    nc = l // q_len

    xn = rmsnorm(u, p["norm"], cfg.norm_eps)
    up = constrain(jnp.einsum("bld,de->ble", xn, p["w_up"].astype(u.dtype)),
                   "batch", "seq", "inner")
    gate = constrain(jnp.einsum("bld,de->ble", xn, p["w_gate"].astype(u.dtype)),
                     "batch", "seq", "inner")
    conv_out, conv_tail = _conv_causal(up, p["conv_w"], p["conv_b"])

    conv_h = conv_out.reshape(b, l, heads, dh)
    up_h = up.reshape(b, l, heads, dh)
    qm = jnp.einsum("blhd,hde->blhe", conv_h, p["wq"].astype(u.dtype))
    km = jnp.einsum("blhd,hde->blhe", conv_h, p["wk"].astype(u.dtype)) * dh ** -0.5
    vm = jnp.einsum("blhd,hde->blhe", up_h, p["wv"].astype(u.dtype))
    gates = jnp.einsum("ble,eg->blg", conv_out, p["w_if"].astype(u.dtype)) \
        + p["b_if"].astype(u.dtype)
    i_gate = gates[..., :heads].astype(jnp.float32)               # log-space input
    f_gate = jax.nn.log_sigmoid(gates[..., heads:].astype(jnp.float32))

    qh = qm.reshape(b, nc, q_len, heads, dh).astype(jnp.float32)
    kh = km.reshape(b, nc, q_len, heads, dh).astype(jnp.float32)
    vh = vm.reshape(b, nc, q_len, heads, dh).astype(jnp.float32)
    del qm, km, vm
    ic = i_gate.reshape(b, nc, q_len, heads)
    fc = f_gate.reshape(b, nc, q_len, heads)
    g = jnp.cumsum(fc, axis=2)                                    # (B,nc,Q,H), <= 0
    # running intra-chunk stabilizer: max_{s<=t} (g_t - g_s + i_s)
    runmax = jax.lax.cummax(ic - g, axis=2)
    intra_max = g + runmax                                        # (B,nc,Q,H)

    causal = jnp.tril(jnp.ones((q_len, q_len), bool))

    c0 = jnp.zeros((b, heads, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, heads, dh), jnp.float32)
    m0 = jnp.full((b, heads), -1e30, jnp.float32)

    def body(carry, inp):
        c_hat, n_hat, m_run = carry
        qk_, kk_, vk_, gk, ick, imaxk = inp                       # (B,Q,H,dh) / (B,Q,H)
        g_q = gk[:, -1]                                           # (B,H) chunk total
        d_t = jnp.maximum(imaxk, m_run[:, None, :] + gk)          # (B,Q,H)
        # intra-chunk
        logw = (gk[:, :, None, :] - gk[:, None, :, :]
                + ick[:, None, :, :] - d_t[:, :, None, :])        # (B,t,s,H)
        w = jnp.where(causal[None, :, :, None], jnp.exp(logw), 0.0)
        qk_scores = jnp.einsum("bthd,bshd->bhts", qk_, kk_,
                               preferred_element_type=jnp.float32)
        num = jnp.einsum("bhts,btsh,bshd->bthd", qk_scores, w, vk_)
        den = jnp.einsum("bhts,btsh->bth", qk_scores, w)
        # inter-chunk (previous state)
        w_int = jnp.exp(m_run[:, None, :] + gk - d_t)             # (B,Q,H), <= 1
        num = num + jnp.einsum("bthd,bhde,bth->bthe", qk_, c_hat, w_int)
        den = den + jnp.einsum("bthd,bhd,bth->bth", qk_, n_hat, w_int)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-d_t))[..., None]
        # carry update (state stabilizer = intra_max at chunk end)
        sstab = imaxk[:, -1]                                      # (B,H)
        m_new = jnp.maximum(m_run + g_q, sstab)
        w_state = jnp.exp(g_q[:, None, :] - gk + ick - sstab[:, None, :])
        c_rel = jnp.einsum("bsh,bshd,bshe->bhde", w_state, kk_, vk_)
        n_rel = jnp.einsum("bsh,bshd->bhd", w_state, kk_)
        scale_old = jnp.exp(m_run + g_q - m_new)
        scale_new = jnp.exp(sstab - m_new)
        c_hat = c_hat * scale_old[:, :, None, None] + c_rel * scale_new[:, :, None, None]
        n_hat = n_hat * scale_old[:, :, None] + n_rel * scale_new[:, :, None]
        return (c_hat, n_hat, m_new), h

    xs = (qh.transpose(1, 0, 2, 3, 4), kh.transpose(1, 0, 2, 3, 4),
          vh.transpose(1, 0, 2, 3, 4), g.transpose(1, 0, 2, 3),
          ic.transpose(1, 0, 2, 3), intra_max.transpose(1, 0, 2, 3))
    (cF, nF, mF), hs = jax.lax.scan(body, (c0, n0, m0), xs)       # (nc,B,Q,H,dh)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(b, l, inner).astype(u.dtype)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    h = constrain(h * jax.nn.silu(gate), "batch", "seq", "inner")
    y = constrain(jnp.einsum("ble,ed->bld", h, p["w_down"].astype(u.dtype)),
                  "batch", "seq", None)
    if return_state:
        return y, {"c": cF, "n": nF, "m": mF, "conv": conv_tail}
    return y


def mlstm_init_state(cfg, batch, dtype=jnp.float32):
    xc = cfg.xlstm
    inner, heads, dh = _mdims(cfg)
    return {
        "c": jnp.zeros((batch, heads, dh, dh), dtype),
        "n": jnp.zeros((batch, heads, dh), dtype),
        "m": jnp.full((batch, heads), -1e30, dtype),
        "conv": jnp.zeros((batch, xc.conv_width - 1, inner), dtype),
    }


def mlstm_decode(p, u, state, cfg):
    """One-step exact recurrent mLSTM (with running-max stabilizer)."""
    inner, heads, dh = _mdims(cfg)
    b = u.shape[0]
    xn = rmsnorm(u, p["norm"], cfg.norm_eps)
    up = jnp.einsum("bld,de->ble", xn, p["w_up"].astype(u.dtype))
    gate = jnp.einsum("bld,de->ble", xn, p["w_gate"].astype(u.dtype))
    conv_out, new_conv = _conv_causal(up, p["conv_w"], p["conv_b"], state["conv"])
    conv_h = conv_out.reshape(b, 1, heads, dh)
    up_h = up.reshape(b, 1, heads, dh)
    qv = jnp.einsum("blhd,hde->blhe", conv_h, p["wq"].astype(u.dtype))[:, 0]
    kv = jnp.einsum("blhd,hde->blhe", conv_h, p["wk"].astype(u.dtype))[:, 0] * dh ** -0.5
    vv = jnp.einsum("blhd,hde->blhe", up_h, p["wv"].astype(u.dtype))[:, 0]
    gates = (jnp.einsum("ble,eg->blg", conv_out, p["w_if"].astype(u.dtype))
             + p["b_if"].astype(u.dtype))[:, 0]
    i_t = gates[:, :heads].astype(jnp.float32)
    f_t = jax.nn.log_sigmoid(gates[:, heads:].astype(jnp.float32))

    m_new = jnp.maximum(f_t + state["m"], i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_t + state["m"] - m_new)
    qh = qv.reshape(b, heads, dh).astype(jnp.float32)
    kh = kv.reshape(b, heads, dh).astype(jnp.float32)
    vh = vv.reshape(b, heads, dh).astype(jnp.float32)
    c = state["c"] * f_p[:, :, None, None] + i_p[:, :, None, None] \
        * kh[:, :, :, None] * vh[:, :, None, :]
    n = state["n"] * f_p[:, :, None] + i_p[:, :, None] * kh
    num = jnp.einsum("bhde,bhd->bhe", c, qh)
    # stabilized normalizer: h_true = num/max(|den|, 1) in true scale, i.e.
    # max(|den_hat|, exp(-m)) in the carried (c,n are *exp(-m)) scale.
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qh)),
                      jnp.exp(-m_new))
    h = (num / den[:, :, None]).reshape(b, 1, inner).astype(u.dtype)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    h = h * jax.nn.silu(gate)
    y = jnp.einsum("ble,ed->bld", h, p["w_down"].astype(u.dtype))
    return y, {"c": c, "n": n, "m": m_new, "conv": new_conv}


# ---------------------------------------------------------------------------
# sLSTM

def spec_slstm(cfg):
    x = cfg.xlstm
    d = cfg.d_model
    heads = cfg.n_heads
    dh = d // heads
    ffn = int(x.proj_factor_s * d)
    return {
        "norm": P((d,), ("embed",), init="zeros"),
        "conv_w": P((x.conv_width, d), (None, "embed"), scale=0.1),
        "conv_b": P((d,), ("embed",), init="zeros"),
        "w_gates": P((d, 4 * d), ("embed", "inner")),            # i,f,z,o
        "r_gates": P((heads, dh, 4 * dh), ("heads", None, None), scale=0.01),
        "b_gates": P((4 * d,), ("inner",), init="zeros"),
        "out_norm": P((d,), ("embed",), init="zeros"),
        "ffn": {
            "w_in": P((d, ffn), ("embed", "mlp")),
            "w_gate": P((d, ffn), ("embed", "mlp")),
            "w_out": P((ffn, d), ("mlp", "embed")),
        },
    }


def slstm_init_state(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    x = cfg.xlstm
    return {
        "c": jnp.zeros((batch, d), dtype),
        "n": jnp.full((batch, d), 1e-6, dtype),
        "h": jnp.zeros((batch, d), dtype),
        "m": jnp.full((batch, d), -1e30, dtype),
        "conv": jnp.zeros((batch, x.conv_width - 1, d), dtype),
    }


def _slstm_cell(p, wx, h_prev, c, n, m, cfg):
    """One step. wx: (B, 4d) precomputed input contribution."""
    heads = cfg.n_heads
    d = cfg.d_model
    dh = d // heads
    b = wx.shape[0]
    hh = h_prev.reshape(b, heads, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh, p["r_gates"].astype(jnp.float32))
    rec = rec.reshape(b, heads, 4, dh).transpose(0, 2, 1, 3).reshape(b, 4 * d)
    gates = wx + rec + p["b_gates"].astype(jnp.float32)
    it, ft, zt, ot = jnp.split(gates, 4, axis=-1)
    m_new = jnp.maximum(ft + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(zt)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm(p, u, cfg, state=None, return_state: bool = False):
    """u: (B, L, D) -> (B, L, D). Sequential scan over time."""
    b, l, d = u.shape
    xn = rmsnorm(u, p["norm"], cfg.norm_eps)
    conv_out, conv_tail = _conv_causal(xn, p["conv_w"], p["conv_b"])
    # i, f gates see the conv branch; z, o the raw branch (xLSTM paper)
    wx_if = jnp.einsum("bld,de->ble",
                       conv_out, p["w_gates"][:, :2 * d].astype(u.dtype))
    wx_zo = jnp.einsum("bld,de->ble",
                       xn, p["w_gates"][:, 2 * d:].astype(u.dtype))
    wx = jnp.concatenate([wx_if, wx_zo], axis=-1).astype(jnp.float32)

    st = state or slstm_init_state(cfg, b)

    def body(carry, wx_t):
        h, c, n, m = carry
        h2, c2, n2, m2 = _slstm_cell(p, wx_t, h, c, n, m, cfg)
        return (h2, c2, n2, m2), h2

    (hF, cF, nF, mF), hs = jax.lax.scan(
        body, (st["h"].astype(jnp.float32), st["c"].astype(jnp.float32),
               st["n"].astype(jnp.float32), st["m"].astype(jnp.float32)),
        wx.transpose(1, 0, 2))
    h_seq = hs.transpose(1, 0, 2).astype(u.dtype)
    h_seq = rmsnorm(h_seq, p["out_norm"], cfg.norm_eps)

    f = p["ffn"]
    hf = jnp.einsum("bld,df->blf", h_seq, f["w_in"].astype(u.dtype))
    gf = jnp.einsum("bld,df->blf", h_seq, f["w_gate"].astype(u.dtype))
    y = jnp.einsum("blf,fd->bld", jax.nn.silu(gf) * hf,
                   f["w_out"].astype(u.dtype))
    if return_state:
        return y, {"c": cF, "n": nF, "h": hF, "m": mF, "conv": conv_tail}
    return y


def slstm_decode(p, u, state, cfg):
    b, _, d = u.shape
    xn = rmsnorm(u, p["norm"], cfg.norm_eps)
    conv_out, new_conv = _conv_causal(xn, p["conv_w"], p["conv_b"], state["conv"])
    wx_if = jnp.einsum("bld,de->ble",
                       conv_out, p["w_gates"][:, :2 * d].astype(u.dtype))[:, 0]
    wx_zo = jnp.einsum("bld,de->ble",
                       xn, p["w_gates"][:, 2 * d:].astype(u.dtype))[:, 0]
    wx = jnp.concatenate([wx_if, wx_zo], axis=-1).astype(jnp.float32)
    h2, c2, n2, m2 = _slstm_cell(p, wx, state["h"].astype(jnp.float32),
                                 state["c"].astype(jnp.float32),
                                 state["n"].astype(jnp.float32),
                                 state["m"].astype(jnp.float32), cfg)
    hn = rmsnorm(h2[:, None, :].astype(u.dtype), p["out_norm"], cfg.norm_eps)
    f = p["ffn"]
    hf = jnp.einsum("bld,df->blf", hn, f["w_in"].astype(u.dtype))
    gf = jnp.einsum("bld,df->blf", hn, f["w_gate"].astype(u.dtype))
    y = jnp.einsum("blf,fd->bld", jax.nn.silu(gf) * hf,
                   f["w_out"].astype(u.dtype))
    return y, {"c": c2, "n": n2, "h": h2, "m": m2, "conv": new_conv}

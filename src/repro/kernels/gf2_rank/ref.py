# repro: noqa RPA501 -- reference oracle: reached from tests/benchmarks, not the runtime roots
"""Pure-jnp oracle for gf2_rank (the battery's own implementation)."""
from repro.stats.tests import gf2_rank32


def gf2_rank_ref(mats):
    return gf2_rank32(mats)

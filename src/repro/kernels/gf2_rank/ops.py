"""jit'd public wrapper: padding + dispatch to the Pallas kernel.

``interpret="auto"`` (the default) compiles the Pallas kernel on real TPU
hardware and falls back to the interpreter on CPU/GPU — callers never
silently interpret on a TPU.
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.gf2_rank.kernel import TILE_M, gf2_rank


def rank32(mats: jax.Array,
           interpret: Union[str, bool] = "auto") -> jax.Array:
    """(M, 32) uint32 -> (M,) int32; pads M up to TILE_M internally."""
    m = mats.shape[0]
    pad = (-m) % TILE_M
    if pad:
        mats = jnp.pad(mats, ((0, pad), (0, 0)))
    return gf2_rank(mats, interpret=resolve_interpret(interpret))[:m]

"""jit'd public wrapper: padding + dispatch to the Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gf2_rank.kernel import TILE_M, gf2_rank


def rank32(mats: jax.Array, interpret: bool = True) -> jax.Array:
    """(M, 32) uint32 -> (M,) int32; pads M up to TILE_M internally."""
    m = mats.shape[0]
    pad = (-m) % TILE_M
    if pad:
        mats = jnp.pad(mats, ((0, pad), (0, 0)))
    return gf2_rank(mats, interpret=interpret)[:m]

"""Pallas TPU kernel: bit-packed GF(2) matrix rank (MatrixRank test hot spot).

TestU01 does word-level Gaussian elimination on CPU. TPU adaptation: a whole
32x32 bit-matrix lives in ONE 32-lane uint32 vector register row, so a VMEM
tile of (TILE_M, 32) holds TILE_M matrices and the 32-step elimination is a
fully vectorized mask/XOR dance on the VPU — no MXU needed, no gather/swap
(pivot selection via argmax over candidate masks).

Grid: one program per TILE_M matrices. BlockSpec keeps the (TILE_M, 32)
tile + (TILE_M,) rank output resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 256


def _rank_kernel(mats_ref, rank_ref):
    rows = mats_ref[...]                                   # (TILE_M, 32) u32
    m = rows.shape[0]
    used = jnp.zeros((m, 32), jnp.bool_)
    rank = jnp.zeros((m,), jnp.int32)
    ridx = jax.lax.broadcasted_iota(jnp.int32, (m, 32), 1)

    def body(i, st):
        rows, used, rank = st
        col = ((rows >> (31 - i).astype(jnp.uint32)) & 1) == 1
        cand = col & ~used
        has = cand.any(axis=1)
        piv = jnp.argmax(cand, axis=1)                     # first candidate
        # dtype pinned: under an ambient-x64 trace (the battery runners)
        # jnp.sum would promote uint32 -> uint64 and break the carry
        pivrow = jnp.sum(jnp.where(ridx == piv[:, None], rows, 0), axis=1,
                         dtype=jnp.uint32)
        pivrow = jnp.where(has, pivrow, 0)
        apply = col & (ridx != piv[:, None])
        rows = jnp.where(apply, rows ^ pivrow[:, None], rows)
        used = used | ((ridx == piv[:, None]) & has[:, None])
        rank = rank + has.astype(jnp.int32)
        return rows, used, rank

    _, _, rank = jax.lax.fori_loop(0, 32, body, (rows, used, rank))
    rank_ref[...] = rank


@functools.partial(jax.jit, static_argnames=("interpret",))
def gf2_rank(mats: jax.Array, interpret: bool = True) -> jax.Array:
    """mats: (M, 32) uint32 (rows of 32x32 bit matrices) -> (M,) int32 ranks.

    M must be a multiple of TILE_M (callers pad; the battery's matrix counts
    are powers of two).
    """
    m = mats.shape[0]
    assert m % TILE_M == 0, m
    grid = (m // TILE_M,)
    return pl.pallas_call(
        _rank_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_M, 32), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_M,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=interpret,
    )(mats)

# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared kernel-layer plumbing: the ``interpret="auto"`` resolution every
``ops.py`` wrapper uses, so importing a kernel on real TPU hardware never
silently runs the Pallas interpreter (and CPU/CI keeps working without a
Mosaic backend)."""
from __future__ import annotations

from typing import Union


def resolve_interpret(interpret: Union[str, bool] = "auto") -> bool:
    """Resolve a Pallas ``interpret`` knob: booleans pass through; "auto"
    compiles the kernel when a TPU backend is present and interprets
    everywhere else."""
    if interpret == "auto":
        import jax
        return jax.default_backend() != "tpu"
    return bool(interpret)

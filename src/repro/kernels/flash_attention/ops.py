# repro: quarantine -- growth-seed attention kernel demo; unrelated to the TestU01 battery kernels
"""jit'd public wrapper: (B, S, H, dh) layout + GQA head grouping.

``interpret="auto"`` (the default) compiles the Pallas kernel on real TPU
hardware and falls back to the interpreter on CPU/GPU.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.flash_attention.kernel import flash_attention


def mha(q, k, v, *, scale, softcap=0.0, causal=True, interpret="auto"):
    """q: (B, S, H, dh); k/v: (B, T, K, dh) with H % K == 0 (GQA repeat)."""
    b, s, h, dh = q.shape
    kh = k.shape[2]
    if kh != h:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], dh)
    o = flash_attention(qf, kf, vf, scale=scale, softcap=softcap,
                        causal=causal, interpret=resolve_interpret(interpret))
    return o.reshape(b, h, s, dh).transpose(0, 2, 1, 3)

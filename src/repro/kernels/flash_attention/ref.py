# repro: quarantine -- growth-seed attention kernel demo; unrelated to the TestU01 battery kernels
"""Pure-jnp oracle for flash_attention."""
import jax.numpy as jnp


def attention_ref(q, k, v, *, scale, softcap=0.0, causal=True):
    """q/k/v: (BH, S, dh), fp32 reference."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        qn, kn = s.shape[1], s.shape[2]
        mask = jnp.tril(jnp.ones((qn, kn), bool))
        s = jnp.where(mask[None], s, -2.3819763e38)
    w = jnp.exp(s - s.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)

# repro: quarantine -- growth-seed attention kernel demo; unrelated to the TestU01 battery kernels
"""Pallas TPU kernel: blocked causal flash attention (fwd, online softmax).

Hardware twin of models/attention.py::_attend_blocked (same math, same
oracle): q/k/v stream through VMEM in (BLOCK_Q, BLOCK_K) tiles; scores live
only tile-at-a-time; running (m, l, acc) scratch carries the online softmax
across the innermost kv grid dim. Supports the gemma2 logit softcap.

Grid: (B*H, n_q_blocks, n_kv_blocks), kv innermost; the output block is
revisited across kv steps and finalized (acc/l) on the last one. BLOCK
sizes are MXU-aligned (128); head_dim should be a multiple of 128 on real
TPUs (interpret mode accepts any).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG = -2.3819763e38


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_s, m_s, l_s, *,
               scale, softcap, causal, n_k):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)
        m_s[...] = jnp.full_like(m_s, NEG)
        l_s[...] = jnp.zeros_like(l_s)

    q = q_ref[0].astype(jnp.float32)                       # (Bq, dh)
    k = k_ref[0].astype(jnp.float32)                       # (Bk, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        qpos = iq * BLOCK_Q + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0)
        kpos = ik * BLOCK_K + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(qpos >= kpos, s, NEG)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_s[...] = l_s[...] * alpha + p.sum(axis=1)
    acc_s[...] = acc_s[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ik == n_k - 1)
    def _final():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-37)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "softcap", "causal",
                                    "interpret"))
def flash_attention(q, k, v, *, scale: float, softcap: float = 0.0,
                    causal: bool = True, interpret: bool = True):
    """q/k/v: (BH, S, dh) -> (BH, S, dh). S % 128 == 0 (callers pad)."""
    bh, s, dh = q.shape
    t = k.shape[1]
    assert s % BLOCK_Q == 0 and t % BLOCK_K == 0, (s, t)
    n_q, n_k = s // BLOCK_Q, t // BLOCK_K
    kern = functools.partial(_fa_kernel, scale=scale, softcap=softcap,
                             causal=causal, n_k=n_k)
    return pl.pallas_call(
        kern,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BLOCK_K, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BLOCK_K, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, dh), jnp.float32),
            pltpu.VMEM((BLOCK_Q,), jnp.float32),
            pltpu.VMEM((BLOCK_Q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

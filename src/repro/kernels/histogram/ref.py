# repro: noqa RPA501 -- reference oracle: reached from tests/benchmarks, not the runtime roots
"""Pure-jnp oracle for the histogram kernel."""
import jax.numpy as jnp


def histogram_ref(idx, k):
    return jnp.bincount(idx, length=k).astype(jnp.float32)

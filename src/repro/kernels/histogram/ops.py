"""jit'd public wrapper: padding (+ tail-bin masking) for histogram.

``interpret="auto"`` (the default) compiles the Pallas kernel on real TPU
hardware and falls back to the interpreter on CPU/GPU — callers never
silently interpret on a TPU.
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp

from repro.kernels import resolve_interpret
from repro.kernels.histogram.kernel import CHUNK, histogram


def bincount(idx: jax.Array, k: int,
             interpret: Union[str, bool] = "auto") -> jax.Array:
    n = idx.shape[0]
    pad = (-n) % CHUNK
    if pad:
        idx = jnp.concatenate([idx, jnp.full((pad,), k, jnp.int32)])
    out = histogram(idx, k + (1 if pad else 0),
                    interpret=resolve_interpret(interpret))
    return out[:k]

"""jit'd public wrapper: padding (+ tail-bin masking) for histogram."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.histogram.kernel import CHUNK, histogram


def bincount(idx: jax.Array, k: int, interpret: bool = True) -> jax.Array:
    n = idx.shape[0]
    pad = (-n) % CHUNK
    if pad:
        idx = jnp.concatenate([idx, jnp.full((pad,), k, jnp.int32)])
    out = histogram(idx, k + (1 if pad else 0), interpret=interpret)
    return out[:k]

"""Pallas TPU kernel: fused bin-count (the gap/poker/weight/serial hot loop).

Scatter-free TPU strategy: a chunk of pre-computed bin indices is compared
against the bin iota — a (CHUNK, K) compare matrix reduced over CHUNK — so
the accumulation is pure VPU work on MXU-friendly 128-lane tiles. The grid
walks chunks; the output block is revisited (constant index_map) and
accumulated across grid steps, with K kept VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 2048


def _hist_kernel(idx_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[...]                                     # (CHUNK,) int32
    k = out_ref.shape[0]
    bins = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], k), 1)
    hit = (idx[:, None] == bins).astype(jnp.float32)
    out_ref[...] += jnp.sum(hit, axis=0)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def histogram(idx: jax.Array, k: int, interpret: bool = True) -> jax.Array:
    """idx: (N,) int32 in [0, k) -> (k,) float32 counts. N % CHUNK == 0."""
    n = idx.shape[0]
    assert n % CHUNK == 0, n
    return pl.pallas_call(
        _hist_kernel,
        grid=(n // CHUNK,),
        in_specs=[pl.BlockSpec((CHUNK,), lambda i: (i,))],
        # repro: vmem-bound repro.stats.backends.HIST_MAX_BINS
        out_specs=pl.BlockSpec((k,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=interpret,
    )(idx)

"""The analyzed file set: sources, parsed ASTs, module-name mapping.

A ``Project`` is a pure mapping ``relpath -> source`` (plus lazy AST and
line caches), so rules are testable on virtual trees: the fixture corpus
(tests/analysis_fixtures) and the mutation tests feed hand-built file
dicts through exactly the code path the CLI runs on the real repo.

Also home to the small shared resolvers every rule family leans on:
module-level integer constants (with cross-module dotted lookup for
``# repro: vmem-bound`` annotations), literal-arithmetic evaluation, and
``repro.*`` import-edge extraction for the reachability family.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.model import is_quarantined

# the subtree the CLI analyzes by default, relative to the repo root
DEFAULT_SUBTREE = os.path.join("src", "repro")


class Project:
    """An immutable set of Python sources keyed by repo-relative path
    (always ``/``-separated, e.g. ``src/repro/core/api.py``)."""

    def __init__(self, files: Dict[str, str]):
        self.files = dict(files)
        self._asts: Dict[str, Optional[ast.Module]] = {}
        self._lines: Dict[str, List[str]] = {}

    @classmethod
    def from_tree(cls, root: str,
                  subtree: str = DEFAULT_SUBTREE) -> "Project":
        """Scan ``root/subtree`` for ``.py`` files (sorted, recursive)."""
        files: Dict[str, str] = {}
        base = os.path.join(root, subtree)
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = sorted(d for d in dirnames
                                 if d != "__pycache__")
            for fname in sorted(filenames):
                if not fname.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fname)
                rel = os.path.relpath(full, root).replace(os.sep, "/")
                with open(full, encoding="utf-8") as f:
                    files[rel] = f.read()
        return cls(files)

    # -- per-file access ---------------------------------------------------

    def paths(self) -> List[str]:
        """All paths, sorted."""
        return sorted(self.files)

    def source(self, path: str) -> str:
        return self.files[path]

    def lines(self, path: str) -> List[str]:
        """Source lines (for comment scanning; cached)."""
        if path not in self._lines:
            self._lines[path] = self.files[path].splitlines()
        return self._lines[path]

    def line(self, path: str, lineno: int) -> str:
        """1-based source line ("" when out of range)."""
        lines = self.lines(path)
        return lines[lineno - 1] if 0 < lineno <= len(lines) else ""

    def tree(self, path: str) -> Optional[ast.Module]:
        """Parsed AST (``None`` for files that fail to parse — the CLI
        reports those as RPA000 internal findings, rules just skip)."""
        if path not in self._asts:
            try:
                self._asts[path] = ast.parse(self.files[path], path)
            except SyntaxError:
                self._asts[path] = None
        return self._asts[path]

    def quarantined(self, path: str) -> bool:
        """Module opted out of analysis via ``# repro: quarantine``."""
        return is_quarantined(self.files[path])

    def walk(self, skip_quarantined: bool = True
             ) -> Iterator[Tuple[str, ast.Module]]:
        """(path, tree) for every parseable module, quarantine-filtered."""
        for path in self.paths():
            if skip_quarantined and self.quarantined(path):
                continue
            tree = self.tree(path)
            if tree is not None:
                yield path, tree

    # -- module-name mapping (src layout) ----------------------------------

    def module_name(self, path: str) -> Optional[str]:
        """``src/repro/core/api.py`` -> ``repro.core.api`` (packages map
        to their ``__init__``'s dotted name); non-src files -> None."""
        if not path.startswith("src/") or not path.endswith(".py"):
            return None
        parts = path[len("src/"):-len(".py")].split("/")
        if parts[-1] == "__init__":
            parts = parts[:-1]
        return ".".join(parts)

    def module_path(self, module: str) -> Optional[str]:
        """Dotted name -> project path (module file or package init)."""
        base = "src/" + module.replace(".", "/")
        for cand in (base + ".py", base + "/__init__.py"):
            if cand in self.files:
                return cand
        return None

    # -- shared resolvers --------------------------------------------------

    def module_constants(self, path: str) -> Dict[str, int]:
        """Module-level ``NAME = <int literal arithmetic>`` bindings."""
        tree = self.tree(path)
        out: Dict[str, int] = {}
        if tree is None:
            return out
        for node in tree.body:
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                val = literal_int(node.value, out)
                if val is not None:
                    out[node.targets[0].id] = val
        return out

    def dotted_constant(self, dotted: str) -> Optional[int]:
        """Resolve ``repro.stats.backends.HIST_MAX_BINS`` (or a bare
        integer string) across the project's module constants."""
        try:
            return int(dotted)
        except ValueError:
            pass
        if "." not in dotted:
            return None
        module, name = dotted.rsplit(".", 1)
        path = self.module_path(module)
        if path is None:
            return None
        return self.module_constants(path).get(name)

    def imports_of(self, path: str) -> Set[str]:
        """Dotted ``repro.*`` module names imported anywhere in the file
        (top-level and function-local; ``from repro.a import b`` yields
        both ``repro.a`` and — when it names a module — ``repro.a.b``)."""
        tree = self.tree(path)
        out: Set[str] = set()
        if tree is None:
            return out
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name.startswith("repro"):
                        out.add(alias.name)
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if not mod.startswith("repro"):
                    continue
                out.add(mod)
                for alias in node.names:
                    if self.module_path(f"{mod}.{alias.name}"):
                        out.add(f"{mod}.{alias.name}")
        return out


def literal_int(node: ast.AST,
                env: Optional[Dict[str, int]] = None) -> Optional[int]:
    """Evaluate constant integer arithmetic (``1 << 16``, ``4 * KB``)
    over literals and ``env`` names; ``None`` when not statically known."""
    env = env or {}
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    if isinstance(node, ast.Name):
        return env.get(node.id)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = literal_int(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        lhs = literal_int(node.left, env)
        rhs = literal_int(node.right, env)
        if lhs is None or rhs is None:
            return None
        ops = {ast.Add: lambda a, b: a + b,
               ast.Sub: lambda a, b: a - b,
               ast.Mult: lambda a, b: a * b,
               ast.FloorDiv: lambda a, b: a // b if b else None,
               ast.Mod: lambda a, b: a % b if b else None,
               ast.LShift: lambda a, b: a << b,
               ast.RShift: lambda a, b: a >> b,
               ast.Pow: lambda a, b: a ** b if b >= 0 else None}
        fn = ops.get(type(node.op))
        return fn(lhs, rhs) if fn else None
    return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jnp.sum`` / ``jax.lax.switch`` attribute chain as a string."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None

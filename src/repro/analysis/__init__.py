"""repro.analysis — repo-aware static analysis for the battery system.

The speedups this reproduction stacks up (compile-once sessions, the
kernel backend registry, jump-ahead stream offsets, campaign grids) all
rest on invariants that used to live in reviewer memory. This package
checks them with a tool instead (DESIGN.md §9): a stdlib-``ast``
analyzer — no third-party dependencies, importable without JAX — with a
rule registry mirroring ``stats.backends.register``, stable finding
codes, inline suppressions, a baseline file for grandfathered findings,
and a ``python -m repro.analysis`` CLI wired as a CI gate.

Rule families (one module per family under ``repro.analysis.rules``):

  RPA1xx  retrace/sync hazards — Python control flow on traced values,
          host concretization (``float``/``int``/``np.*``/``.item()``)
          inside traced code, traced closures mutating Python state
  RPA2xx  cache-key audit — every ``RunSpec`` field the compiled-program
          construction reads must appear in the session's trace-cache/
          table keys (the PR 4 resolved-backend bug class)
  RPA3xx  kernel contracts — backend registry closure, integer-dtype
          pins against ambient-x64 promotion (the gf2_rank bug class),
          Pallas block working sets under a static VMEM budget (the
          ``HIST_MAX_BINS`` discipline, generalized)
  RPA4xx  registry/version closure — ``COUNTER_BASED`` vs ``offset``
          signatures, checkpoint/ledger writer layouts matched by
          reader upgrade paths (v1/v2/v3 + ``CampaignLedger``)
  RPA5xx  import-graph reachability — modules unreachable from the
          battery system carry an explicit quarantine annotation

Inline controls (scanned from source comments, never executed):

  ``# repro: noqa RPA123``             suppress that code on this line
  ``# repro: quarantine -- reason``    (first lines of a module) exempt
                                       a dead seed module from analysis
  ``# repro: runtime-arg``             classify a ``RunSpec`` field as a
                                       runtime argument, not a key field
  ``# repro: vmem-bound <const>``      bound a symbolic Pallas block dim

Typical use::

    PYTHONPATH=src python -m repro.analysis --strict --json report.json
"""
from repro.analysis.driver import run_analysis  # noqa: F401
from repro.analysis.model import Baseline, Finding  # noqa: F401
from repro.analysis.project import Project  # noqa: F401
from repro.analysis.registry import RULES, get_rule, register, rules  # noqa: F401

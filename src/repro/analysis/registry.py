"""Rule registry — the ``stats.backends.register`` idiom for analyzers.

Every rule is a function ``fn(project) -> Iterable[Finding]`` registered
under a stable code (``RPA101``, ...). Codes are permanent: a retired
rule's code is never reused (suppressions and baselines reference them).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, Iterable, List

_CODE_RE = re.compile(r"^RPA\d{3}$")

_REGISTRY: Dict[str, "Rule"] = {}


@dataclasses.dataclass(frozen=True)
class Rule:
    """One registered analyzer: stable ``code``, short kebab ``name``,
    one-line ``summary``, and the checking function."""
    code: str
    name: str
    summary: str
    fn: Callable

    @property
    def family(self) -> str:
        """``RPA101`` -> ``RPA1xx`` (rules ship one module per family)."""
        return self.code[:4] + "xx"


def register(code: str, name: str, summary: str) -> Callable:
    """Decorator: ``@register("RPA101", "traced-python-branch", ...)``.
    Re-registering a code is an error — codes are append-only."""
    if not _CODE_RE.match(code):
        raise ValueError(f"rule code must match RPAnnn, got {code!r}")

    def deco(fn: Callable) -> Callable:
        if code in _REGISTRY:
            raise ValueError(f"rule code {code} already registered "
                             f"({_REGISTRY[code].name})")
        _REGISTRY[code] = Rule(code, name, summary, fn)
        return fn
    return deco


def rules() -> List[Rule]:
    """Every registered rule, sorted by code (loads the rule modules)."""
    import repro.analysis.rules  # noqa: F401  (registration side effect)
    return [_REGISTRY[c] for c in sorted(_REGISTRY)]


def get_rule(code: str) -> Rule:
    """Lookup by code (after ensuring rule modules are loaded)."""
    import repro.analysis.rules  # noqa: F401
    if code not in _REGISTRY:
        raise KeyError(f"unknown rule code {code!r}; "
                       f"known: {sorted(_REGISTRY)}")
    return _REGISTRY[code]


def RULES() -> Dict[str, Rule]:
    """The live registry mapping (code -> Rule), post-load."""
    import repro.analysis.rules  # noqa: F401
    return dict(_REGISTRY)


def run_rules(project, codes: Iterable[str] = ()) -> List:
    """Run the selected rules (default: all) and return sorted findings."""
    selected = rules()
    if codes:
        want = set(codes)
        selected = [r for r in selected if r.code in want]
    findings = []
    for rule in selected:
        findings.extend(rule.fn(project))
    return sorted(findings, key=lambda f: f.sort_key())

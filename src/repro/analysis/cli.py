"""``python -m repro.analysis`` — the static-analysis CLI / CI gate.

Exit codes: 0 clean, 1 findings (or, under ``--strict``, stale baseline
entries), 2 usage/internal error. The human report goes to stdout; the
machine report goes wherever ``--json`` points (``-`` for stdout).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.analysis.driver import run_analysis
from repro.analysis.model import Baseline
from repro.analysis.project import DEFAULT_SUBTREE, Project
from repro.analysis.registry import rules

BASELINE_NAME = ".repro-analysis-baseline.json"


def _find_root(start: str) -> Optional[str]:
    """Walk up from ``start`` to the first directory holding the
    analyzed subtree (``src/repro``)."""
    cur = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(cur, DEFAULT_SUBTREE)):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return None
        cur = parent


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-aware static analysis for the battery system "
                    "(DESIGN.md §9).")
    p.add_argument("--root", default=None,
                   help="repo root (default: walk up from cwd to the "
                        "first directory containing src/repro)")
    p.add_argument("--strict", action="store_true",
                   help="CI gate mode: also fail on stale baseline "
                        "entries")
    p.add_argument("--json", dest="json_path", default=None,
                   metavar="PATH",
                   help="write the JSON report to PATH ('-' = stdout)")
    p.add_argument("--baseline", default=None, metavar="PATH",
                   help=f"baseline file (default: <root>/{BASELINE_NAME})")
    p.add_argument("--write-baseline", action="store_true",
                   help="rewrite the baseline to grandfather the "
                        "current findings, then exit 0")
    p.add_argument("--rules", default=None, metavar="CODES",
                   help="comma-separated rule codes to run "
                        "(default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for r in rules():
            print(f"{r.code}  {r.name:28s} {r.summary}")
        return 0

    root = args.root or _find_root(os.getcwd())
    if root is None or not os.path.isdir(
            os.path.join(root, DEFAULT_SUBTREE)):
        print(f"error: no {DEFAULT_SUBTREE}/ under "
              f"{args.root or os.getcwd()!r} (pass --root)",
              file=sys.stderr)
        return 2

    baseline_path = args.baseline or os.path.join(root, BASELINE_NAME)
    baseline = Baseline.load(baseline_path)
    project = Project.from_tree(root)
    codes = [c.strip() for c in args.rules.split(",")] if args.rules \
        else []
    result = run_analysis(project, baseline, codes)

    if args.write_baseline:
        new_baseline = Baseline(
            {f.key() for f in result.findings + result.baselined},
            baseline_path)
        new_baseline.save()
        print(f"wrote {len(new_baseline.entries)} entr"
              f"{'y' if len(new_baseline.entries) == 1 else 'ies'} "
              f"to {baseline_path}")
        return 0

    for f in result.syntax_errors + result.findings:
        print(f)
    for entry in result.stale_baseline:
        print(f"{entry['path']}: stale baseline entry "
              f"{entry['code']}: {entry['message']}")

    n = len(result.findings) + len(result.syntax_errors)
    print(f"{result.files_scanned} files scanned: {n} finding(s), "
          f"{len(result.suppressed)} suppressed, "
          f"{len(result.baselined)} baselined, "
          f"{len(result.stale_baseline)} stale baseline entr"
          f"{'y' if len(result.stale_baseline) == 1 else 'ies'}")

    if args.json_path:
        report = json.dumps(result.to_json(args.strict), indent=2,
                            sort_keys=True)
        if args.json_path == "-":
            print(report)
        else:
            os.makedirs(os.path.dirname(args.json_path) or ".",
                        exist_ok=True)
            with open(args.json_path, "w") as f:
                f.write(report + "\n")

    return result.exit_code(args.strict)


if __name__ == "__main__":
    sys.exit(main())

"""Findings model: stable codes, JSON shape, suppressions, baseline.

A ``Finding`` is one rule violation anchored to (path, line, col). Its
identity for baselining is ``(code, path, message)`` — deliberately
line-free, so unrelated edits above a grandfathered finding don't churn
the baseline file (same discipline as the job-id-keyed checkpoints:
identity never depends on position).
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

# one inline-comment grammar for every control the analyzer understands
NOQA_RE = re.compile(r"#\s*repro:\s*noqa\s+((?:RPA\d{3}[,\s]*)+)")
QUARANTINE_RE = re.compile(r"#\s*repro:\s*quarantine\b")
VMEM_BOUND_RE = re.compile(r"#\s*repro:\s*vmem-bound\s+([\w.]+)")
RUNTIME_ARG_RE = re.compile(r"#\s*repro:\s*runtime-arg\b")
FAULT_BOUNDARY_RE = re.compile(r"#\s*repro:\s*fault-boundary\b")

# a quarantine marker must sit near the top of the module — it describes
# the whole file, not one line
QUARANTINE_HEAD_LINES = 15


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation: stable ``code`` (RPAxxx), the registered rule
    name, the repo-relative ``path`` and 1-based ``line``/``col`` anchor,
    and a human message. Sorts by (path, line, code) for stable output."""
    code: str
    rule: str
    path: str
    line: int
    col: int
    message: str

    def key(self) -> Tuple[str, str, str]:
        """Baseline identity: line-free, so edits don't churn it."""
        return (self.code, self.path, self.message)

    def to_json(self) -> dict:
        """The ``--json`` wire shape (tests/test_analysis_cli.py pins it)."""
        return {"code": self.code, "rule": self.rule, "path": self.path,
                "line": self.line, "col": self.col, "message": self.message}

    def sort_key(self) -> tuple:
        """Stable report order."""
        return (self.path, self.line, self.col, self.code, self.message)

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.code} [{self.rule}] {self.message}")


def noqa_codes(source_line: str) -> Set[str]:
    """Codes suppressed by a ``# repro: noqa RPA101, RPA102`` comment."""
    m = NOQA_RE.search(source_line)
    if not m:
        return set()
    return set(re.findall(r"RPA\d{3}", m.group(1)))


def is_quarantined(source: str) -> bool:
    """True when the module's head carries a ``# repro: quarantine``
    comment LINE (a docstring merely mentioning the marker — e.g. the
    analyzer's own docs — does not quarantine the module)."""
    head = source.splitlines()[:QUARANTINE_HEAD_LINES]
    return any(line.lstrip().startswith("#")
               and QUARANTINE_RE.search(line) for line in head)


def split_suppressed(findings: Iterable[Finding],
                     lines_of) -> Tuple[List[Finding], List[Finding]]:
    """Partition findings into (kept, suppressed) by per-line noqa.
    ``lines_of(path)`` returns the file's source lines."""
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        lines = lines_of(f.path)
        line = lines[f.line - 1] if 0 < f.line <= len(lines) else ""
        (suppressed if f.code in noqa_codes(line) else kept).append(f)
    return kept, suppressed


class Baseline:
    """Grandfathered findings (``.repro-analysis-baseline.json``).

    The file is a sorted list of ``{code, path, message}`` entries. Policy
    (DESIGN.md §9): the baseline exists so the gate can be adopted on a
    tree with known findings — it ships EMPTY and should stay empty; new
    findings are fixed or ``noqa``-suppressed with a justification, not
    baselined. ``--strict`` additionally fails on STALE entries (baselined
    findings that no longer occur), so the file can only shrink."""

    def __init__(self, entries: Optional[Set[Tuple[str, str, str]]] = None,
                 path: Optional[str] = None):
        self.entries = entries or set()
        self.path = path

    @classmethod
    def load(cls, path: str) -> "Baseline":
        """Read the baseline file (a missing file is an empty baseline)."""
        if not os.path.exists(path):
            return cls(set(), path)
        with open(path) as f:
            data = json.load(f)
        entries = {(e["code"], e["path"], e["message"])
                   for e in data.get("findings", [])}
        return cls(entries, path)

    def save(self, path: Optional[str] = None) -> None:
        """Write the sorted baseline (``--write-baseline``)."""
        path = path or self.path
        data = {"version": 1,
                "findings": [{"code": c, "path": p, "message": m}
                             for c, p, m in sorted(self.entries)]}
        with open(path, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")

    def split(self, findings: Iterable[Finding]
              ) -> Tuple[List[Finding], List[Finding], List[dict]]:
        """(new, baselined, stale): findings not in the baseline, findings
        it grandfathers, and entries it holds that no longer occur."""
        new: List[Finding] = []
        baselined: List[Finding] = []
        seen: Set[Tuple[str, str, str]] = set()
        for f in findings:
            if f.key() in self.entries:
                baselined.append(f)
                seen.add(f.key())
            else:
                new.append(f)
        stale = [{"code": c, "path": p, "message": m}
                 for c, p, m in sorted(self.entries - seen)]
        return new, baselined, stale


def counts_by_code(findings: Iterable[Finding]) -> Dict[str, int]:
    """``{code: n}`` histogram for the JSON report."""
    out: Dict[str, int] = {}
    for f in findings:
        out[f.code] = out.get(f.code, 0) + 1
    return dict(sorted(out.items()))

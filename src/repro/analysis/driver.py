"""Analysis driver: rules -> suppressions -> baseline -> report.

``run_analysis`` is the one entry point both the CLI and the test suite
call, so fixture projects and the real tree flow through identical
logic: run the registered rules, drop per-line ``noqa`` suppressions,
split what remains against the baseline, and wrap it all in an
``AnalysisResult`` whose ``to_json()`` is the ``--json`` wire shape
(golden-keyed by tests/test_analysis_cli.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional

from repro.analysis.model import (Baseline, Finding, counts_by_code,
                                  split_suppressed)
from repro.analysis.project import Project
from repro.analysis.registry import rules, run_rules

JSON_VERSION = 1


@dataclasses.dataclass
class AnalysisResult:
    """Everything one analyzer pass produced, pre-partitioned."""
    findings: List[Finding]          # new, actionable
    baselined: List[Finding]         # grandfathered by the baseline
    suppressed: List[Finding]        # per-line noqa'd
    stale_baseline: List[dict]       # baseline entries that no longer fire
    files_scanned: int
    syntax_errors: List[Finding]     # RPA000 — unparseable files

    def clean(self, strict: bool = False) -> bool:
        """No actionable findings (strict also rejects stale baseline
        entries — the baseline may only shrink)."""
        if self.findings or self.syntax_errors:
            return False
        return not (strict and self.stale_baseline)

    def exit_code(self, strict: bool = False) -> int:
        return 0 if self.clean(strict) else 1

    def to_json(self, strict: bool = False) -> dict:
        """The ``--json`` report shape. Keys are append-only."""
        return {
            "version": JSON_VERSION,
            "strict": strict,
            "clean": self.clean(strict),
            "files_scanned": self.files_scanned,
            "rules": [{"code": r.code, "name": r.name,
                       "summary": r.summary} for r in rules()],
            "findings": [f.to_json() for f in
                         self.syntax_errors + self.findings],
            "baselined": [f.to_json() for f in self.baselined],
            "suppressed": [f.to_json() for f in self.suppressed],
            "stale_baseline": list(self.stale_baseline),
            "counts": {
                "findings": len(self.findings) + len(self.syntax_errors),
                "baselined": len(self.baselined),
                "suppressed": len(self.suppressed),
                "stale_baseline": len(self.stale_baseline),
                "by_code": counts_by_code(
                    self.syntax_errors + self.findings),
            },
        }


def _syntax_errors(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for path in project.paths():
        if project.tree(path) is None:
            out.append(Finding("RPA000", "syntax-error", path, 1, 1,
                               "file does not parse"))
    return out


def run_analysis(project: Project,
                 baseline: Optional[Baseline] = None,
                 codes: Iterable[str] = ()) -> AnalysisResult:
    """Run the selected rules (default: all) over ``project`` and
    partition the findings against ``baseline`` (default: empty)."""
    baseline = baseline or Baseline()
    raw = run_rules(project, codes)
    kept, suppressed = split_suppressed(raw, project.lines)
    new, baselined, stale = baseline.split(kept)
    return AnalysisResult(
        findings=new,
        baselined=baselined,
        suppressed=suppressed,
        stale_baseline=stale,
        files_scanned=len(project.paths()),
        syntax_errors=_syntax_errors(project),
    )

"""RPA2xx — the RunSpec -> trace-cache key audit.

The PR 4 bug class: ``PoolSession`` caches compiled programs under a key
tuple, and any ``spec`` field that influences compiled-program
construction but is missing from that key silently serves a stale
program when only that field changes (the original instance: ``backend``
was consumed by ``_compiled`` but keyed only as the raw string, so
``backend="auto"`` and ``backend="accelerated"`` aliased after
resolution). These rules re-derive the key/consumption sets from the AST
on every run:

  RPA201  a session-class method that builds or fetches compiled state
          (``_compiled``/``_runner``) reads a ``spec`` field that the key
          tuples (``cache_key``/``_table_key``, plus per-runner key
          tuples assigned inside ``_runner``) do not cover; also fired
          when ``cache_key`` is not a superset of ``_table_key``.
  RPA202  a ``RunSpec`` dataclass field is neither covered by the key
          tuples nor annotated ``# repro: runtime-arg`` (the explicit
          classification: "this field feeds the runner as a traced
          argument / host-side policy knob, never the compiled program").

A "session class" is any ClassDef with at least one key method
(``cache_key``/``_table_key``) and at least one consumer method
(``_compiled``/``_runner``) — structural, so the fixtures and any future
session types get the same audit as ``PoolSession``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.model import RUNTIME_ARG_RE, Finding
from repro.analysis.project import Project
from repro.analysis.registry import register

KEY_METHODS = ("cache_key", "_table_key")
CONSUMER_METHODS = ("_compiled", "_runner")


def _methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body
            if isinstance(n, ast.FunctionDef)}


def session_classes(tree: ast.Module) -> Iterator[ast.ClassDef]:
    """ClassDefs that look like compile-once sessions."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        methods = _methods(node)
        if any(m in methods for m in KEY_METHODS) \
                and any(m in methods for m in CONSUMER_METHODS):
            yield node


def _spec_param(fn: ast.FunctionDef) -> Optional[str]:
    """The spec parameter: second positional arg (after ``self``)."""
    args = fn.args.posonlyargs + fn.args.args
    return args[1].arg if len(args) >= 2 else None


def spec_fields(node: ast.AST, spec: str,
                env: Optional[Dict[str, Set[str]]] = None) -> Set[str]:
    """``spec.X`` field names referenced in an expression, following the
    local dataflow ``env`` (name -> set of originating spec fields)."""
    env = env or {}
    out: Set[str] = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and \
                isinstance(n.value, ast.Name) and n.value.id == spec:
            out.add(n.attr)
        elif isinstance(n, ast.Name) and n.id in env:
            out |= env[n.id]
    return out


def _local_env(fn: ast.FunctionDef, spec: str) -> Dict[str, Set[str]]:
    """Map each local name to the spec fields its value derives from
    (single forward pass; good enough for the straight-line key/compile
    methods this rule audits)."""
    env: Dict[str, Set[str]] = {}
    for stmt in ast.walk(fn):
        if not isinstance(stmt, ast.Assign):
            continue
        fields = spec_fields(stmt.value, spec, env)
        if not fields:
            continue
        for t in stmt.targets:
            for n in ast.walk(t):
                if isinstance(n, ast.Name):
                    env.setdefault(n.id, set()).update(fields)
    return env


def _key_tuple_fields(fn: ast.FunctionDef) -> Set[str]:
    """Spec fields appearing in the tuple a key method returns."""
    spec = _spec_param(fn)
    if spec is None:
        return set()
    env = _local_env(fn, spec)
    out: Set[str] = set()
    for stmt in ast.walk(fn):
        if isinstance(stmt, ast.Return) and stmt.value is not None:
            out |= spec_fields(stmt.value, spec, env)
    return out


def _consumed_fields(fn: ast.FunctionDef) -> Set[str]:
    """Every spec field a consumer method reads."""
    spec = _spec_param(fn)
    if spec is None:
        return set()
    return {n.attr for n in ast.walk(fn)
            if isinstance(n, ast.Attribute)
            and isinstance(n.value, ast.Name) and n.value.id == spec}


def _runner_key_fields(fn: ast.FunctionDef) -> Set[str]:
    """Spec fields folded into per-runner key tuples — any tuple literal
    assigned to a local inside ``_runner`` (e.g. ``rk = (w, g, grid)``
    where ``g``/``grid`` derive from spec fields)."""
    spec = _spec_param(fn)
    if spec is None:
        return set()
    env = _local_env(fn, spec)
    out: Set[str] = set()
    for stmt in ast.walk(fn):
        if isinstance(stmt, ast.Assign) \
                and isinstance(stmt.value, ast.Tuple):
            out |= spec_fields(stmt.value, spec, env)
    return out


def _runspec_class(tree: ast.Module) -> Optional[ast.ClassDef]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "RunSpec":
            return node
    return None


def _property_fields(cls: ast.ClassDef) -> Dict[str, Set[str]]:
    """Property name -> the ``self.X`` fields it reads (so a key that
    consumes ``spec.n_generators`` covers the ``generators`` field)."""
    out: Dict[str, Set[str]] = {}
    for node in cls.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        if not any(isinstance(d, ast.Name) and d.id == "property"
                   for d in node.decorator_list):
            continue
        out[node.name] = {n.attr for n in ast.walk(node)
                          if isinstance(n, ast.Attribute)
                          and isinstance(n.value, ast.Name)
                          and n.value.id == "self"}
    return out


@register("RPA201", "cache-key-missing-field",
          "compiled-program construction reads a spec field the "
          "trace-cache key does not cover")
def rpa201(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for path, tree in project.walk():
        for cls in session_classes(tree):
            methods = _methods(cls)
            cache_key = methods.get("cache_key")
            table_key = methods.get("_table_key")
            ck_fields = _key_tuple_fields(cache_key) if cache_key \
                else set()
            tk_fields = _key_tuple_fields(table_key) if table_key \
                else set()
            # the session key must subsume the table key: a field that
            # distinguishes compiled tables must distinguish sessions
            if cache_key is not None and table_key is not None:
                missing = sorted(tk_fields - ck_fields)
                if missing:
                    out.append(Finding(
                        "RPA201", "cache-key-missing-field", path,
                        cache_key.lineno, cache_key.col_offset + 1,
                        f"{cls.name}.cache_key drops spec field(s) "
                        f"{missing} that _table_key depends on — "
                        f"sessions with different compiled tables "
                        f"would alias"))
            compiled = methods.get("_compiled")
            if compiled is not None:
                key = tk_fields or ck_fields
                missing = sorted(_consumed_fields(compiled) - key)
                if missing:
                    out.append(Finding(
                        "RPA201", "cache-key-missing-field", path,
                        compiled.lineno, compiled.col_offset + 1,
                        f"{cls.name}._compiled reads spec field(s) "
                        f"{missing} missing from the compiled-table "
                        f"key — a stale program would be served when "
                        f"only those fields change"))
            runner = methods.get("_runner")
            if runner is not None:
                covered = (ck_fields | tk_fields
                           | _runner_key_fields(runner))
                missing = sorted(_consumed_fields(runner) - covered)
                if missing:
                    out.append(Finding(
                        "RPA201", "cache-key-missing-field", path,
                        runner.lineno, runner.col_offset + 1,
                        f"{cls.name}._runner reads spec field(s) "
                        f"{missing} not covered by the session or "
                        f"per-runner keys — a cached runner would be "
                        f"reused across those values"))
    return out


@register("RPA202", "unclassified-spec-field",
          "RunSpec field neither in a trace-cache key nor annotated "
          "# repro: runtime-arg")
def rpa202(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for path, tree in project.walk():
        runspec = _runspec_class(tree)
        sessions = list(session_classes(tree))
        if runspec is None or not sessions:
            continue
        props = _property_fields(runspec)
        covered: Set[str] = set()
        for cls in sessions:
            methods = _methods(cls)
            for name in KEY_METHODS:
                if name in methods:
                    covered |= _key_tuple_fields(methods[name])
            if "_runner" in methods:
                covered |= _runner_key_fields(methods["_runner"])
        # resolve property reads down to the dataclass fields they touch
        for prop in list(covered):
            covered |= props.get(prop, set())
        for node in runspec.body:
            if not isinstance(node, ast.AnnAssign) \
                    or not isinstance(node.target, ast.Name):
                continue
            field = node.target.id
            if field in covered:
                continue
            if RUNTIME_ARG_RE.search(project.line(path, node.lineno)):
                continue
            out.append(Finding(
                "RPA202", "unclassified-spec-field", path,
                node.lineno, node.col_offset + 1,
                f"RunSpec.{field} is neither part of a trace-cache "
                f"key nor annotated `# repro: runtime-arg` — classify "
                f"it so key drift is detectable"))
    return out

"""RPA3xx — kernel contracts: registry closure, dtype pins, VMEM budget.

  RPA301  backend registry closure — every kernel family registered with
          an ``accelerated`` backend must also have a ``reference``
          entry (``resolve()`` falls back to reference; an accelerated-
          only family would fail exactly when the fallback matters).
          Registration sites are collected from direct
          ``register(name, backend, fn)`` calls AND loops over dict
          literals (``for k, f in T.KERNELS.items(): register(k, ...)``),
          resolving the dict across module imports.
  RPA302  unpinned integer reduction in a Pallas kernel body — the
          gf2_rank bug class: ``jnp.sum`` over integer data without
          ``dtype=`` promotes to int64 under ambient x64 and changes
          the wrapped uint32 arithmetic the kernel relies on. Float
          operands (tracked through ``.astype(jnp.float32)`` locals)
          are exempt.
  RPA303  Pallas block working set — the per-step VMEM working set
          implied by every ``BlockSpec`` shape in a ``pallas_call``
          must be statically bounded and under ``VMEM_BUDGET_BYTES``
          (the ``HIST_MAX_BINS`` discipline, generalized). A dimension
          that is not literal arithmetic needs an inline
          ``# repro: vmem-bound <int | dotted.CONST>`` annotation naming
          its static bound.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.model import VMEM_BOUND_RE, Finding
from repro.analysis.project import (Project, dotted_name, literal_int)
from repro.analysis.registry import register

# per-step working-set budget across all blocks of one pallas_call.
# Real VMEM is ~16 MiB/core; 4 MiB of 4-byte elements leaves headroom
# for double buffering and scratch, and every shipped kernel fits.
VMEM_BUDGET_BYTES = 4 * 1024 * 1024
ELEMENT_BYTES = 4  # uint32/int32/float32 repo-wide

BACKEND_NAMES = {"reference", "accelerated"}
INT_REDUCTIONS = {"sum", "prod", "cumsum", "cumprod", "dot"}
FLOAT_PREFIXES = ("float", "bfloat")


# -- RPA301 ----------------------------------------------------------------

def _module_dicts(tree: ast.Module) -> Dict[str, ast.Dict]:
    """Module-level ``NAME = {...}`` / ``NAME: T = {...}`` dict literals."""
    out: Dict[str, ast.Dict] = {}
    for node in tree.body:
        target = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            target = node.targets[0].id
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name):
            target = node.target.id
        value = getattr(node, "value", None)
        if target is not None and isinstance(value, ast.Dict):
            out[target] = value
    return out


def _dict_str_keys(d: ast.Dict) -> Set[str]:
    return {k.value for k in d.keys
            if isinstance(k, ast.Constant) and isinstance(k.value, str)}


def _import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Local alias -> dotted module (``from repro.stats import tests as
    T`` makes ``T`` -> ``repro.stats.tests``)."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out[alias.asname or alias.name.split(".")[0]] = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                out[alias.asname or alias.name] = \
                    f"{node.module}.{alias.name}"
    return out


def _resolve_dict_keys(project: Project, path: str, tree: ast.Module,
                       node: ast.expr) -> Optional[Set[str]]:
    """String keys of the dict literal ``node`` refers to — a local
    module-level dict or an imported one (``T.KERNELS``)."""
    local = _module_dicts(tree)
    if isinstance(node, ast.Name):
        if node.id in local:
            return _dict_str_keys(local[node.id])
        return None
    dotted = dotted_name(node)
    if dotted is None or "." not in dotted:
        return None
    alias, attr = dotted.rsplit(".", 1)
    module = _import_aliases(tree).get(alias)
    if module is None:
        return None
    mpath = project.module_path(module)
    if mpath is None:
        return None
    mtree = project.tree(mpath)
    if mtree is None:
        return None
    remote = _module_dicts(mtree)
    if attr in remote:
        return _dict_str_keys(remote[attr])
    return None


def _enclosing_for(tree: ast.Module, call: ast.Call
                   ) -> Optional[ast.For]:
    """The For loop whose body contains ``call`` (module level only)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.For) and any(
                call is c for c in ast.walk(node)):
            return node
    return None


def _registrations(project: Project, path: str, tree: ast.Module
                   ) -> Dict[str, Set[Tuple[str, int]]]:
    """backend -> {(family, lineno)} from every ``register(...)`` site."""
    out: Dict[str, Set[Tuple[str, int]]] = {b: set()
                                            for b in BACKEND_NAMES}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or len(node.args) < 3:
            continue
        fname = dotted_name(node.func) or ""
        if fname.split(".")[-1] != "register":
            continue
        backend_arg = node.args[1]
        if not (isinstance(backend_arg, ast.Constant)
                and backend_arg.value in BACKEND_NAMES):
            continue
        backend = backend_arg.value
        name_arg = node.args[0]
        if isinstance(name_arg, ast.Constant) \
                and isinstance(name_arg.value, str):
            out[backend].add((name_arg.value, node.lineno))
            continue
        # loop-registration: resolve the iterated dict's keys
        loop = _enclosing_for(tree, node)
        if loop is None:
            continue
        it = loop.iter
        if isinstance(it, ast.Call) \
                and isinstance(it.func, ast.Attribute) \
                and it.func.attr == "items":
            keys = _resolve_dict_keys(project, path, tree, it.func.value)
            if keys is not None:
                out[backend] |= {(k, loop.lineno) for k in keys}
    return out


@register("RPA301", "backend-registry-closure",
          "accelerated kernel family registered without a reference "
          "fallback entry")
def rpa301(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for path, tree in project.walk():
        regs = _registrations(project, path, tree)
        if not regs["accelerated"]:
            continue
        reference = {name for name, _ in regs["reference"]}
        for name, lineno in sorted(regs["accelerated"]):
            if name not in reference:
                out.append(Finding(
                    "RPA301", "backend-registry-closure", path,
                    lineno, 1,
                    f"kernel family '{name}' has an accelerated "
                    f"backend but no reference entry — resolve() "
                    f"has nothing to fall back to"))
    return out


# -- RPA302 ----------------------------------------------------------------

def _kernel_functions(tree: ast.Module) -> Iterator[ast.FunctionDef]:
    """Pallas kernel bodies: named first-arg of a ``pallas_call``, or a
    function whose every parameter is a ``*_ref``."""
    by_call: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and node.args:
            fname = dotted_name(node.func) or ""
            if fname.split(".")[-1] == "pallas_call" \
                    and isinstance(node.args[0], ast.Name):
                by_call.add(node.args[0].id)
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        args = node.args.posonlyargs + node.args.args
        all_refs = bool(args) and all(a.arg.endswith("_ref")
                                      for a in args)
        if node.name in by_call or all_refs:
            yield node


def _is_float_dtype(node: ast.AST) -> bool:
    """``jnp.float32`` / ``np.float64`` / ``"float32"``-ish."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value.startswith(FLOAT_PREFIXES)
    dotted = dotted_name(node) or ""
    return dotted.split(".")[-1].startswith(FLOAT_PREFIXES)


def _float_known(node: ast.AST, env: Set[str]) -> bool:
    """Statically known to be floating point (so integer promotion
    under ambient x64 cannot change its values)."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, float)
    if isinstance(node, ast.Name):
        return node.id in env
    if isinstance(node, ast.Call):
        # .astype may hang off any expression (a Compare, a slice...),
        # so check the attribute directly rather than via dotted_name
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "astype" and node.args:
            return _is_float_dtype(node.args[0])
        fname = dotted_name(node.func) or ""
        last = fname.split(".")[-1]
        if last in {"zeros", "ones", "full", "zeros_like", "ones_like",
                    "empty"}:
            for kw in node.keywords:
                if kw.arg == "dtype":
                    return _is_float_dtype(kw.value)
            # jnp default dtype is float32 (x64 floats don't wrap)
            return True
        if last in {"where", "maximum", "minimum", "clip"}:
            return any(_float_known(a, env) for a in node.args)
        return False
    if isinstance(node, ast.BinOp):
        return _float_known(node.left, env) \
            or _float_known(node.right, env)
    return False


@register("RPA302", "unpinned-integer-reduction",
          "integer jnp reduction in a Pallas kernel without a dtype= "
          "pin (ambient-x64 promotion changes wrapped arithmetic)")
def rpa302(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for path, tree in project.walk():
        for fn in _kernel_functions(tree):
            env: Set[str] = set()
            for stmt in ast.walk(fn):
                if isinstance(stmt, ast.Assign) \
                        and _float_known(stmt.value, env):
                    for t in stmt.targets:
                        if isinstance(t, ast.Name):
                            env.add(t.id)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                fname = dotted_name(node.func) or ""
                parts = fname.split(".")
                if parts[0] != "jnp" or parts[-1] not in INT_REDUCTIONS:
                    continue
                if any(kw.arg == "dtype" for kw in node.keywords):
                    continue
                if node.args and _float_known(node.args[0], env):
                    continue
                out.append(Finding(
                    "RPA302", "unpinned-integer-reduction", path,
                    node.lineno, node.col_offset + 1,
                    f"`{fname}` in kernel `{fn.name}` has no dtype= "
                    f"pin — under ambient x64 an integer operand "
                    f"promotes to int64 and wrapped uint32 arithmetic "
                    f"changes (the gf2_rank bug class)"))
    return out


# -- RPA303 ----------------------------------------------------------------

def _block_dims(call: ast.Call) -> List[Tuple[ast.expr, int]]:
    """(dim expression, lineno) for a BlockSpec's shape tuple."""
    if not call.args:
        return []
    shape = call.args[0]
    if not isinstance(shape, (ast.Tuple, ast.List)):
        return []
    return [(elt, elt.lineno) for elt in shape.elts]


def _blockspecs(call: ast.Call) -> Iterator[ast.Call]:
    """Every BlockSpec(...) constructor in a pallas_call's in/out specs."""
    for kw in call.keywords:
        if kw.arg not in {"in_specs", "out_specs", "scratch_shapes"}:
            continue
        for node in ast.walk(kw.value):
            if isinstance(node, ast.Call):
                fname = dotted_name(node.func) or ""
                if fname.split(".")[-1] == "BlockSpec":
                    yield node


def _annotated_bound(project: Project, path: str,
                     linenos: List[int]) -> Optional[int]:
    """A ``# repro: vmem-bound <X>`` annotation on any of the lines
    (trailing on the dim or BlockSpec line, or a full-line comment
    immediately above the BlockSpec)."""
    for lineno in linenos:
        m = VMEM_BOUND_RE.search(project.line(path, lineno))
        if m:
            return project.dotted_constant(m.group(1))
    return None


@register("RPA303", "vmem-budget",
          "Pallas block shapes must be statically bounded and fit the "
          "VMEM working-set budget")
def rpa303(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for path, tree in project.walk():
        consts = project.module_constants(path)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fname = dotted_name(node.func) or ""
            if fname.split(".")[-1] != "pallas_call":
                continue
            total = 0
            bounded = True
            for spec in _blockspecs(node):
                block = ELEMENT_BYTES
                for dim, lineno in _block_dims(spec):
                    val = literal_int(dim, consts)
                    if val is None:
                        val = _annotated_bound(
                            project, path,
                            [lineno, spec.lineno, spec.lineno - 1])
                    if val is None:
                        bounded = False
                        out.append(Finding(
                            "RPA303", "vmem-budget", path,
                            lineno, dim.col_offset + 1,
                            f"pallas_call block dimension is not "
                            f"statically bounded — annotate the "
                            f"BlockSpec with `# repro: vmem-bound "
                            f"<int | dotted.CONST>` naming its "
                            f"static bound"))
                        continue
                    block *= max(val, 1)
                total += block
            if bounded and total > VMEM_BUDGET_BYTES:
                out.append(Finding(
                    "RPA303", "vmem-budget", path,
                    node.lineno, node.col_offset + 1,
                    f"pallas_call working set is {total} bytes "
                    f"({total // 1024} KiB) of 4-byte elements — "
                    f"over the {VMEM_BUDGET_BYTES // (1024 * 1024)} "
                    f"MiB VMEM budget; shrink the block shapes or "
                    f"add a grid dimension"))
    return out

"""RPA4xx — registry and wire-format closure.

  RPA401  offset/COUNTER_BASED closure — jump-ahead stream offsets are
          only sound for counter-based generators. In any module that
          defines both a ``GENERATORS`` dict literal and a
          ``COUNTER_BASED`` tuple: every counter-based entry's block
          function must take an ``offset`` parameter, every generator
          whose block function takes ``offset`` must be listed in
          ``COUNTER_BASED`` (else the capability is silently dropped
          at the ``gen_block_by_id`` switch), and ``COUNTER_BASED``
          must be a subset of the registry.
  RPA403  dynamic-registry declaration — the BitSource plugin registry
          (``rng.sources.register_generator``) took over RPA401's
          static closure: every ``register_generator(...)`` call must
          declare ``counter_based=`` explicitly (the offset capability
          cannot be inferred from an out-of-repo block function), and a
          module that registers generators must not ALSO define a
          static ``COUNTER_BASED`` tuple literal — the live registry
          (``counter_based_names()``) is the single source of truth,
          and a parallel static tuple would drift the moment a plugin
          registers.
  RPA402  version upgrade path — a class whose ``save`` writes a flat
          leaf list (the msgpack wire format) and whose ``load`` reads
          it back via ``load_flat`` must (a) accept the layout it
          writes: the writer's leaf count appears among the reader's
          ``len(leaves) ==/!=`` constants, and (b) actually check any
          ``*VERSION*`` constant it serializes. This is the invariant
          the Checkpoint v1/v2/v3 upgrade chain and the CampaignLedger
          maintain by hand.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.analysis.model import Finding
from repro.analysis.project import Project, dotted_name
from repro.analysis.registry import register


# -- RPA401 ----------------------------------------------------------------

def _module_assign(tree: ast.Module, name: str) -> Optional[ast.AST]:
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id == name:
            return node
        if isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name) \
                and node.target.id == name and node.value is not None:
            return node
    return None


def _str_elements(node: ast.expr) -> Optional[Set[str]]:
    if not isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return None
    out = set()
    for elt in node.elts:
        if not (isinstance(elt, ast.Constant)
                and isinstance(elt.value, str)):
            return None
        out.add(elt.value)
    return out


@register("RPA401", "offset-registry-closure",
          "COUNTER_BASED generators must take offset=, and only they "
          "may")
def rpa401(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for path, tree in project.walk():
        gens_node = _module_assign(tree, "GENERATORS")
        cb_node = _module_assign(tree, "COUNTER_BASED")
        if gens_node is None or cb_node is None:
            continue
        gens_value = gens_node.value
        counter_based = _str_elements(cb_node.value)
        if not isinstance(gens_value, ast.Dict) or counter_based is None:
            continue
        fns = {n.name: n for n in tree.body
               if isinstance(n, ast.FunctionDef)}
        registry: Dict[str, Optional[ast.FunctionDef]] = {}
        for key, val in zip(gens_value.keys, gens_value.values):
            if isinstance(key, ast.Constant) \
                    and isinstance(key.value, str):
                fn = fns.get(val.id) if isinstance(val, ast.Name) \
                    else None
                registry[key.value] = fn
        for name in sorted(counter_based - set(registry)):
            out.append(Finding(
                "RPA401", "offset-registry-closure", path,
                cb_node.lineno, 1,
                f"COUNTER_BASED lists '{name}' which is not in the "
                f"GENERATORS registry"))
        for name, fn in sorted(registry.items()):
            if fn is None:
                continue
            params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                      + fn.args.kwonlyargs)}
            takes_offset = "offset" in params
            if name in counter_based and not takes_offset:
                out.append(Finding(
                    "RPA401", "offset-registry-closure", path,
                    fn.lineno, 1,
                    f"generator '{name}' is declared COUNTER_BASED "
                    f"but `{fn.name}` takes no offset= parameter — "
                    f"jump-ahead would silently restart the stream"))
            elif name not in counter_based and takes_offset:
                out.append(Finding(
                    "RPA401", "offset-registry-closure", path,
                    fn.lineno, 1,
                    f"generator '{name}' takes offset= but is not in "
                    f"COUNTER_BASED — its jump-ahead capability is "
                    f"dropped at the offset dispatch"))
    return out


# -- RPA403 ----------------------------------------------------------------

@register("RPA403", "dynamic-registry-declaration",
          "register_generator calls must declare counter_based=; "
          "registering modules must not keep a static COUNTER_BASED "
          "tuple")
def rpa403(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for path, tree in project.walk():
        calls = [node for node in ast.walk(tree)
                 if isinstance(node, ast.Call)
                 and (dotted_name(node.func) or "").split(".")[-1]
                 == "register_generator"]
        if not calls:
            continue
        for call in calls:
            if any(kw.arg == "counter_based" for kw in call.keywords):
                continue
            out.append(Finding(
                "RPA403", "dynamic-registry-declaration", path,
                call.lineno, call.col_offset + 1,
                "register_generator(...) without an explicit "
                "counter_based= keyword — the offset capability of a "
                "registered source must be DECLARED; stream offsets, "
                "over-decomposition and campaign grids all dispatch "
                "on it"))
        cb_node = _module_assign(tree, "COUNTER_BASED")
        if cb_node is not None \
                and _str_elements(cb_node.value) is not None:
            out.append(Finding(
                "RPA403", "dynamic-registry-declaration", path,
                cb_node.lineno, 1,
                "module registers generators dynamically but also "
                "defines a static COUNTER_BASED tuple — derive it from "
                "the live registry (rng.sources.counter_based_names) "
                "so plugins cannot drift it"))
    return out


# -- RPA402 ----------------------------------------------------------------

def _writer_layout(fn: ast.FunctionDef) -> Optional[ast.List]:
    """The leaf-list literal handed to ``io.save(path, [leaves...])``."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and len(node.args) >= 2 \
                and isinstance(node.args[1], ast.List):
            fname = dotted_name(node.func) or ""
            if fname.split(".")[-1] == "save":
                return node.args[1]
    return None


def _uses_load_flat(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            fname = dotted_name(node.func) or ""
            if fname.split(".")[-1] == "load_flat":
                return True
    return False


def _accepted_lengths(fn: ast.FunctionDef) -> Set[int]:
    """Constants N from ``len(x) == N`` / ``len(x) != N`` comparisons."""
    out: Set[int] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Compare) or len(node.ops) != 1:
            continue
        if not isinstance(node.ops[0], (ast.Eq, ast.NotEq)):
            continue
        sides = (node.left, node.comparators[0])
        has_len = any(isinstance(s, ast.Call)
                      and (dotted_name(s.func) or "") == "len"
                      for s in sides)
        if not has_len:
            continue
        for s in sides:
            if isinstance(s, ast.Constant) and isinstance(s.value, int):
                out.add(s.value)
    return out


def _version_names(leaves: ast.List) -> Set[str]:
    """``*VERSION*`` constants serialized in the leaf list (e.g.
    ``np.int64(CKPT_VERSION)``)."""
    return {n.id for n in ast.walk(leaves)
            if isinstance(n, ast.Name) and "VERSION" in n.id}


@register("RPA402", "version-upgrade-path",
          "wire-format writers must have a matching reader upgrade "
          "path (leaf count + version check)")
def rpa402(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for path, tree in project.walk():
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            methods = {n.name: n for n in cls.body
                       if isinstance(n, ast.FunctionDef)}
            save, load = methods.get("save"), methods.get("load")
            if save is None or load is None:
                continue
            leaves = _writer_layout(save)
            if leaves is None or not _uses_load_flat(load):
                continue
            accepted = _accepted_lengths(load)
            n = len(leaves.elts)
            if accepted and n not in accepted:
                out.append(Finding(
                    "RPA402", "version-upgrade-path", path,
                    save.lineno, save.col_offset + 1,
                    f"{cls.name}.save writes {n} leaves but "
                    f"{cls.name}.load only accepts layouts of "
                    f"{sorted(accepted)} — the reader cannot load "
                    f"what the writer produces"))
            load_names = {node.id for node in ast.walk(load)
                          if isinstance(node, ast.Name)}
            for vname in sorted(_version_names(leaves)):
                if vname not in load_names:
                    out.append(Finding(
                        "RPA402", "version-upgrade-path", path,
                        save.lineno, save.col_offset + 1,
                        f"{cls.name}.save serializes `{vname}` but "
                        f"{cls.name}.load never checks it — version "
                        f"drift would pass silently"))
    return out

"""Rule families. Importing this package registers every rule.

One module per family (the code prefix is the family):

  trace.py             RPA1xx  retrace/sync hazards in traced code
  cachekey.py          RPA2xx  RunSpec -> trace-cache key audit
  kernels.py           RPA3xx  backend registry + Pallas kernel contracts
  registry_closure.py  RPA4xx  offset/COUNTER_BASED + wire-version closure
  reach.py             RPA5xx  import-graph reachability / quarantine
"""
from repro.analysis.rules import cachekey  # noqa: F401
from repro.analysis.rules import kernels  # noqa: F401
from repro.analysis.rules import reach  # noqa: F401
from repro.analysis.rules import registry_closure  # noqa: F401
from repro.analysis.rules import trace  # noqa: F401

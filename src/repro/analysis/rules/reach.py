"""RPA5xx — import-graph reachability and the quarantine discipline.

The tree still carries modules from the growth seed (an LM training
stack: ``models/``, ``configs/``, ``train/``, ``kernels/flash_attention``)
that nothing in the battery system imports. Rather than deleting them
under the feet of the tier-1 tests that still exercise them, each one
carries a ``# repro: quarantine -- reason`` annotation in its module
head, and this family keeps that classification honest in both
directions:

  RPA501  a module unreachable from the battery-system roots has no
          quarantine annotation — either wire it in or annotate it.
  RPA502  a quarantined module IS reachable from the roots — the
          annotation is stale (or live code grew an import into
          quarantined territory); the import edge is named.

Roots: ``repro.core`` (the session/battery engine), the
``repro.launch.battery`` CLI, the serve layer (``repro.serve`` and its
``repro.launch.serve`` daemon CLI), and ``repro.analysis`` itself —
the serve daemon is an entry point like the battery CLI, so its
subtree must stay honestly classified too. Reaching a
module also reaches its ancestor package ``__init__``s (importing
``repro.a.b`` executes ``repro/a/__init__``). The family no-ops on
projects that contain no root module, so single-file fixture trees
stay silent.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.model import Finding
from repro.analysis.project import Project
from repro.analysis.registry import register

# a module is a root when its dotted name equals one of these or sits
# under one of them
ROOT_PREFIXES = ("repro.core", "repro.launch.battery", "repro.serve",
                 "repro.launch.serve", "repro.analysis")


def _is_root(module: str) -> bool:
    return any(module == p or module.startswith(p + ".")
               for p in ROOT_PREFIXES)


def _ancestor_packages(module: str) -> List[str]:
    parts = module.split(".")
    return [".".join(parts[:i]) for i in range(1, len(parts))]


def reachable_modules(project: Project
                      ) -> Optional[Tuple[Set[str], Dict[str, str]]]:
    """(reachable dotted names, module -> one importing module) via BFS
    from the roots; ``None`` when the project has no root modules."""
    modules: Dict[str, str] = {}
    for path in project.paths():
        name = project.module_name(path)
        if name is not None:
            modules[name] = path
    roots = sorted(m for m in modules if _is_root(m))
    if not roots:
        return None
    via: Dict[str, str] = {}
    seen: Set[str] = set()
    queue = list(roots)
    while queue:
        mod = queue.pop(0)
        if mod in seen or mod not in modules:
            continue
        seen.add(mod)
        # importing a module executes its ancestor package __init__s
        for pkg in _ancestor_packages(mod):
            if pkg in modules and pkg not in seen:
                via.setdefault(pkg, mod)
                queue.append(pkg)
        for imp in sorted(project.imports_of(modules[mod])):
            for target in [imp] + _ancestor_packages(imp):
                if target in modules and target not in seen:
                    via.setdefault(target, mod)
                    queue.append(target)
    return seen, via


@register("RPA501", "unreachable-module",
          "module unreachable from the battery-system roots lacks a "
          "quarantine annotation")
def rpa501(project: Project) -> List[Finding]:
    result = reachable_modules(project)
    if result is None:
        return []
    reachable, _via = result
    out: List[Finding] = []
    for path in project.paths():
        module = project.module_name(path)
        if module is None or module in reachable:
            continue
        if project.quarantined(path):
            continue
        out.append(Finding(
            "RPA501", "unreachable-module", path, 1, 1,
            f"module `{module}` is unreachable from the battery "
            f"system roots {list(ROOT_PREFIXES)} — wire it in or "
            f"annotate it `# repro: quarantine -- <reason>`"))
    return out


@register("RPA502", "stale-quarantine",
          "quarantined module is reachable from the battery-system "
          "roots")
def rpa502(project: Project) -> List[Finding]:
    result = reachable_modules(project)
    if result is None:
        return []
    reachable, via = result
    out: List[Finding] = []
    for path in project.paths():
        module = project.module_name(path)
        if module is None or module not in reachable:
            continue
        if not project.quarantined(path):
            continue
        importer = via.get(module)
        edge = f" (imported via `{importer}`)" if importer else ""
        out.append(Finding(
            "RPA502", "stale-quarantine", path, 1, 1,
            f"module `{module}` carries a quarantine annotation but "
            f"is reachable from the battery system{edge} — drop the "
            f"annotation or cut the import"))
    return out

"""RPA1xx — retrace/sync hazards inside traced functions.

The battery hot path stays fast only while its jitted round functions
compile once and never sync. The classic ways to lose that silently:

  RPA101  Python ``if``/``while``/``assert`` on a traced value — either a
          TracerBoolConversionError at runtime or, worse, a retrace per
          distinct concrete value when the operand happens to be weakly
          typed.
  RPA102  host concretization inside traced code — ``float()``/``int()``/
          ``bool()`` or a ``np.*`` call on a traced value, or ``.item()``;
          each one is a device sync and a trace-time constant bake.
  RPA103  a traced function mutating closed-over Python state (appending
          to a module-level list, writing a global dict): the mutation
          happens at *trace* time, once per compilation, not per call.
  RPA106  fault-injection API (``FaultInjector`` / ``apply_round`` /
          ``inject_round_faults``) called inside traced code — faults
          must be injected at the host-side runner boundary (DESIGN.md
          §12) or they bake into the compile cache and stop being
          replayable. A genuine boundary function in a known-traced
          *module* (never a structurally-traced function) opts out with
          a ``# repro: fault-boundary`` comment on its ``def`` line.

What counts as traced code:

  * every function in the known-traced modules (``rng/generators.py``,
    ``stats/tests.py``, ``stats/backends.py``, ``stats/special.py``,
    ``core/pool.py``, everything under ``kernels/``),
  * any function decorated with ``jit`` / ``shard_map`` /
    ``functools.partial(shard_map, ...)`` / ``pl.when(...)``,
  * any function passed by name into a ``jax.*`` transform or a
    ``pallas_call`` (``jax.lax.cond``/``switch``/``scan`` operands, etc.),
  * Pallas kernel bodies (every parameter ends in ``_ref``).

Taintedness is deliberately conservative: a value is traced when it is
(derived from) the result of a ``jnp.*``/``jax.*`` call. Function
parameters are NOT assumed traced — battery kernels take static shape
params (``kbits``, ``maxlen``) alongside traced arrays, and flagging
``float(1 << kbits)`` would drown the signal. ``.shape``/``.dtype``/
``.ndim``/``.size`` reads are always static.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.model import FAULT_BOUNDARY_RE, Finding
from repro.analysis.project import Project, dotted_name
from repro.analysis.registry import register

# modules whose every function body runs under trace (prefix match)
TRACED_MODULE_PATHS = (
    "src/repro/core/pool.py",
    "src/repro/rng/generators.py",
    "src/repro/stats/tests.py",
    "src/repro/stats/backends.py",
    "src/repro/stats/special.py",
    "src/repro/kernels/",
)

# attribute reads that are static even on traced values
STATIC_ATTRS = {"shape", "dtype", "ndim", "size"}

# call roots whose results are traced values
TRACED_ROOTS = {"jnp", "jax"}

# builtins / namespaces that concretize (sync) a traced operand
CONCRETIZERS = {"float", "int", "bool"}
HOST_ROOTS = {"np", "numpy"}

# mutating method names on closed-over containers
MUTATORS = {"append", "add", "update", "extend", "insert", "pop",
            "setdefault", "clear", "remove", "discard"}

# fault-injection API call names (last dotted component) — host-side only
FAULT_API = {"FaultInjector", "inject_round_faults", "round_faults",
             "apply_round"}


def _decorator_traced(dec: ast.AST) -> bool:
    """``@jax.jit`` / ``@jit`` / ``@functools.partial(shard_map, ...)`` /
    ``@pl.when(...)`` — the decorated function body is traced."""
    name = dotted_name(dec)
    if name is not None:
        return name.split(".")[-1] in {"jit", "shard_map"}
    if isinstance(dec, ast.Call):
        fname = dotted_name(dec.func) or ""
        last = fname.split(".")[-1]
        if last in {"jit", "shard_map", "when"}:
            return True
        if last == "partial" and dec.args:
            inner = dotted_name(dec.args[0]) or ""
            return inner.split(".")[-1] in {"jit", "shard_map"}
    return False


def _names_passed_to_transforms(tree: ast.Module) -> Set[str]:
    """Function names handed to ``jax.*`` transforms / ``shard_map`` /
    ``pallas_call`` anywhere in the module — their bodies are traced."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fname = dotted_name(node.func) or ""
        last = fname.split(".")[-1]
        if not (fname.startswith("jax.")
                or last in {"shard_map", "pallas_call", "jit", "vmap"}):
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Name):
                out.add(arg.id)
            elif isinstance(arg, (ast.List, ast.Tuple)):
                for elt in arg.elts:
                    if isinstance(elt, ast.Name):
                        out.add(elt.id)
    return out


def _is_kernel_body(fn: ast.FunctionDef) -> bool:
    """Pallas kernels take only ``*_ref`` parameters."""
    args = fn.args.posonlyargs + fn.args.args
    return bool(args) and all(a.arg.endswith("_ref") for a in args)


def _functions(tree: ast.Module) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def traced_functions(path: str, tree: ast.Module
                     ) -> List[ast.FunctionDef]:
    """The functions in ``path`` whose bodies run under trace."""
    module_traced = any(path.startswith(p) for p in TRACED_MODULE_PATHS)
    by_call = _names_passed_to_transforms(tree)
    out = []
    for fn in _functions(tree):
        if (module_traced or fn.name in by_call
                or any(_decorator_traced(d) for d in fn.decorator_list)
                or _is_kernel_body(fn)):
            out.append(fn)
    return out


def _tainted(node: ast.AST, env: Set[str]) -> bool:
    """Is this expression (derived from) a traced value?"""
    if isinstance(node, ast.Attribute):
        if node.attr in STATIC_ATTRS:
            return False
        return _tainted(node.value, env)
    if isinstance(node, ast.Call):
        fname = dotted_name(node.func) or ""
        if fname.split(".")[0] in TRACED_ROOTS:
            return True
        return (any(_tainted(a, env) for a in node.args)
                or any(_tainted(k.value, env) for k in node.keywords)
                or _tainted(node.func, env))
    if isinstance(node, ast.Name):
        return node.id in env
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                         ast.Lambda)):
        return False
    return any(_tainted(child, env)
               for child in ast.iter_child_nodes(node))


def _own_statements(fn: ast.FunctionDef) -> Iterator[ast.stmt]:
    """Statements of ``fn`` excluding nested def bodies (nested traced
    functions are analyzed on their own; attributing their hazards to the
    enclosing function would double-report)."""
    stack: List[ast.stmt] = list(fn.body)
    while stack:
        stmt = stack.pop(0)
        yield stmt
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        for field in ("body", "orelse", "finalbody", "handlers"):
            for child in getattr(stmt, field, []):
                if isinstance(child, ast.ExceptHandler):
                    stack.extend(child.body)
                elif isinstance(child, ast.stmt):
                    stack.append(child)


def _local_names(fn: ast.FunctionDef) -> Set[str]:
    """Parameters plus every name bound inside the function body."""
    names = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                             + fn.args.kwonlyargs)}
    for a in (fn.args.vararg, fn.args.kwarg):
        if a is not None:
            names.add(a.arg)
    for stmt in _own_statements(fn):
        targets: List[ast.AST] = []
        if isinstance(stmt, ast.Assign):
            targets = list(stmt.targets)
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For)):
            targets = [stmt.target]
        elif isinstance(stmt, ast.With):
            targets = [i.optional_vars for i in stmt.items
                       if i.optional_vars is not None]
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            names.add(stmt.name)
        for t in targets:
            for node in ast.walk(t):
                if isinstance(node, ast.Name):
                    names.add(node.id)
        for node in ast.walk(stmt):
            if isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                 ast.GeneratorExp)):
                for gen in node.generators:
                    for n in ast.walk(gen.target):
                        if isinstance(n, ast.Name):
                            names.add(n.id)
            elif isinstance(node, ast.NamedExpr):
                names.add(node.target.id)
    return names


def _root_name(node: ast.AST) -> Optional[str]:
    """Peel ``x[i].y`` chains down to the root ``Name``."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _stmt_exprs(stmt: ast.stmt) -> Iterator[ast.expr]:
    """The statement's OWN expression children (child statements are
    visited separately by ``_own_statements`` — walking them here would
    double-report)."""
    for _field, value in ast.iter_fields(stmt):
        if isinstance(value, ast.expr):
            yield value
        elif isinstance(value, list):
            for item in value:
                if isinstance(item, ast.expr):
                    yield item


def _analyze_fn(path: str, fn: ast.FunctionDef
                ) -> Iterator[Tuple[str, ast.AST, str]]:
    """Yield (code, node, message) hazards for one traced function."""
    env: Set[str] = set()
    locals_ = _local_names(fn)

    def note_assign(stmt: ast.stmt) -> None:
        value = getattr(stmt, "value", None)
        if value is None or not _tainted(value, env):
            return
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        for t in targets:
            for node in ast.walk(t):
                if isinstance(node, ast.Name):
                    env.add(node.id)

    for stmt in _own_statements(fn):
        # RPA101 — Python control flow on a traced condition
        if isinstance(stmt, (ast.If, ast.While)) \
                and _tainted(stmt.test, env):
            kind = "if" if isinstance(stmt, ast.If) else "while"
            yield ("RPA101", stmt.test,
                   f"Python `{kind}` on a traced value in "
                   f"`{fn.name}` — use jnp.where/lax.cond (this "
                   f"retraces or raises under jit)")
        elif isinstance(stmt, ast.Assert) and _tainted(stmt.test, env):
            yield ("RPA101", stmt.test,
                   f"`assert` on a traced value in `{fn.name}` — "
                   f"use checkify or a host-side precondition")

        # RPA103 — assignment into closed-over state (the statement
        # itself; mutator-method calls are caught in the expression walk)
        if isinstance(stmt, (ast.Assign, ast.AugAssign)):
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            for t in targets:
                if isinstance(t, (ast.Subscript, ast.Attribute)):
                    root = _root_name(t)
                    if root is not None and root not in locals_:
                        yield ("RPA103", t,
                               f"traced `{fn.name}` writes into "
                               f"closed-over `{root}` — mutation "
                               f"happens once at trace time, not "
                               f"per call")

        exprs = [] if isinstance(stmt, (ast.FunctionDef,
                                        ast.AsyncFunctionDef,
                                        ast.ClassDef)) else \
            [n for e in _stmt_exprs(stmt) for n in ast.walk(e)]
        for node in exprs:
            # RPA102 — host sync / concretization
            if isinstance(node, ast.Call):
                fname = dotted_name(node.func) or ""
                parts = fname.split(".")
                args_tainted = any(_tainted(a, env) for a in node.args)
                if parts[0] in CONCRETIZERS and len(parts) == 1 \
                        and args_tainted:
                    yield ("RPA102", node,
                           f"`{fname}()` concretizes a traced value in "
                           f"`{fn.name}` — forces a device sync and "
                           f"bakes a trace-time constant")
                elif parts[0] in HOST_ROOTS and args_tainted:
                    yield ("RPA102", node,
                           f"host `{fname}()` call on a traced value "
                           f"in `{fn.name}` — move to jnp or hoist "
                           f"out of the traced region")
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" \
                        and _tainted(node.func.value, env):
                    yield ("RPA102", node,
                           f"`.item()` on a traced value in "
                           f"`{fn.name}` — device sync inside "
                           f"traced code")
            # RPA103 — mutator-method call on closed-over state
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in MUTATORS:
                root = _root_name(node.func.value)
                if root is not None and root not in locals_:
                    yield ("RPA103", node,
                           f"traced `{fn.name}` calls "
                           f"`.{node.func.attr}()` on closed-over "
                           f"`{root}` — mutation happens once at "
                           f"trace time, not per call")

        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            note_assign(stmt)


def _run_family(project: Project, want: str) -> List[Finding]:
    from repro.analysis.registry import get_rule
    rule = get_rule(want)
    out: List[Finding] = []
    for path, tree in project.walk():
        for fn in traced_functions(path, tree):
            for code, node, msg in _analyze_fn(path, fn):
                if code != want:
                    continue
                out.append(Finding(code, rule.name, path,
                                   getattr(node, "lineno", fn.lineno),
                                   getattr(node, "col_offset", 0) + 1,
                                   msg))
    return out


@register("RPA101", "traced-python-branch",
          "Python if/while/assert on a traced value inside traced code")
def rpa101(project: Project) -> List[Finding]:
    return _run_family(project, "RPA101")


@register("RPA102", "traced-host-sync",
          "float()/int()/np.*/.item() concretizing a traced value")
def rpa102(project: Project) -> List[Finding]:
    return _run_family(project, "RPA102")


@register("RPA103", "traced-closure-mutation",
          "traced function mutates closed-over Python state")
def rpa103(project: Project) -> List[Finding]:
    return _run_family(project, "RPA103")


def _has_fault_boundary(project: Project, path: str,
                        fn: ast.FunctionDef) -> bool:
    """True when the def region (``def`` line through the first body
    line — where a multi-line signature's comment can sit) carries a
    ``# repro: fault-boundary`` annotation."""
    end = fn.body[0].lineno if fn.body else fn.lineno
    return any(FAULT_BOUNDARY_RE.search(project.line(path, ln))
               for ln in range(fn.lineno, end + 1))


@register("RPA106", "fault-injection-in-trace",
          "fault-injection API called inside traced code")
def rpa106(project: Project) -> List[Finding]:
    """Fault injection is a host-side concern: a ``FaultInjector`` /
    ``apply_round`` / ``inject_round_faults`` call inside traced code
    would perturb results at *trace* time — baked into the compile
    cache, fired once per compilation instead of once per round, and
    unreplayable from ``(plan, seed)``. Only functions in the
    known-traced module allowlist may opt out (the boundary shim in
    ``core/pool.py`` is host-side code that merely *lives* in a traced
    module); structurally-traced functions (decorated, transform-passed,
    kernel bodies) never can."""
    from repro.analysis.registry import get_rule
    rule = get_rule("RPA106")
    out: List[Finding] = []
    for path, tree in project.walk():
        module_traced = any(path.startswith(p)
                            for p in TRACED_MODULE_PATHS)
        by_call = _names_passed_to_transforms(tree)
        for fn in _functions(tree):
            structural = (fn.name in by_call
                          or any(_decorator_traced(d)
                                 for d in fn.decorator_list)
                          or _is_kernel_body(fn))
            if not (module_traced or structural):
                continue
            if not structural and _has_fault_boundary(project, path, fn):
                continue
            for stmt in _own_statements(fn):
                for expr in _stmt_exprs(stmt):
                    for node in ast.walk(expr):
                        if not isinstance(node, ast.Call):
                            continue
                        fname = dotted_name(node.func) or ""
                        if fname.split(".")[-1] not in FAULT_API:
                            continue
                        out.append(Finding(
                            "RPA106", rule.name, path, node.lineno,
                            node.col_offset + 1,
                            f"traced `{fn.name}` calls fault-injection "
                            f"API `{fname}` — inject at the host-side "
                            f"runner boundary (DESIGN.md §12), or mark "
                            f"a genuine boundary in a traced module "
                            f"with `# repro: fault-boundary`"))
    return out

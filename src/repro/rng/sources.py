"""The BitSource layer: pluggable generators + external bitstreams.

The paper's HTCondor pool never cared where the bits came from — it
shipped an executable, a battery and a stream of numbers. This module is
that indifference made explicit: every layer above (pool, api, campaign,
serve, launch) consumes an abstract **BitSource** instead of a name in a
closed generator dict, so the same adaptive batteries screen

  ``GeneratorSource``  an in-repo (or runtime-registered) generator — a
                       lane of the compiled ``lax.switch`` the pool's
                       jitted round program dispatches over; and
  ``CapturedSource``   bits we did NOT generate: a memory-mapped
                       ``.npy`` / raw-u32 capture (nonce dumps,
                       hardware-RNG output, a rival library's stream),
                       sharded by stream, entering the device program as
                       a prefetched host buffer rather than a switch
                       lane (``pool.gather_captured_bits``).

The generator registry is a PLUGIN surface (the ``register_policy`` /
``stats.backends.register`` discipline): ``register_generator`` appends
a block function under a stable, monotonically-assigned ``gen_id``, so
out-of-repo generators join newly-traced switches without invalidating
executables compiled before they existed (``PoolSession._runner`` keeps
per-switch-width slots). Ids are assignment-order stable: a restarted
process that re-registers the same generators in the same order (the
``--register`` CLI surface) resumes any checkpoint or ledger that named
them.

Offset convention (the ONE canonical spelling): ``offset=None`` means
"no offset — trace the offset-free path"; any integer or traced value
means "read words ``[offset, offset + n)``". Sources that cannot seek
(``counter_based=False``) raise the typed ``OffsetNotSupportedError``
from the single ``require_offsetable`` gate — every layer funnels its
refusal through here instead of re-implementing the check.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import os
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import numpy as np


class OffsetNotSupportedError(ValueError):
    """A non-zero stream offset was requested from a source that cannot
    seek (``counter_based=False``) — e.g. ``mwc``'s lag-1 carry chain
    has no cheap jump-ahead. Subclasses ``ValueError`` so pre-BitSource
    callers that caught the untyped refusal keep working."""


class CapturedBitsError(ValueError):
    """A ``CapturedSource`` read ran past the captured material (stream
    shard index or word range out of bounds) — the finite-file analogue
    of a generator's inexhaustible (seed, stream) sequence."""


def require_offsetable(source: "BitSource", offset,
                       where: str = "stream offset") -> None:
    """The single offset-capability gate: raise the typed
    ``OffsetNotSupportedError`` when ``offset`` is a non-zero Python int
    and ``source`` is not counter-based. ``None`` (the canonical
    "no offset" spelling) and 0 always pass."""
    if offset is None or not int(offset):
        return
    if not source.counter_based:
        raise OffsetNotSupportedError(
            f"source {source.name!r} is not offset-continuable "
            f"(counter_based=False); it cannot take a non-zero "
            f"{where}")


# ---------------------------------------------------------------------------
# the generator plugin registry


@dataclasses.dataclass(frozen=True)
class RegisteredGenerator:
    """One registry row: the block function, its stable switch lane id,
    and its declared offset capability."""
    name: str
    gen_id: int
    block_fn: Callable
    counter_based: bool


_REGISTRY: Dict[str, RegisteredGenerator] = {}

# live views, shared BY OBJECT with rng.generators for back-compat:
# mutated in place by register/unregister so imported references stay
# current after dynamic registration
GENERATORS: Dict[str, Callable] = {}
GEN_IDS: Dict[str, int] = {}


def _ensure_builtins() -> None:
    """Populate the registry with the in-repo generators on first use.

    Built-ins register as a side effect of importing
    ``repro.rng.generators``; a caller that reaches the registry
    through this module alone (``capture_generator`` in a fresh
    process, an external ``--register`` hook) must see the same nine
    lanes at the same ids, so every registry read bootstraps them
    lazily. The import is a no-op once ``rng.generators`` is loaded."""
    if not _REGISTRY:
        import repro.rng.generators  # noqa: F401 (registers built-ins)


def register_generator(name: str, block_fn: Callable, *,
                       counter_based: bool) -> RegisteredGenerator:
    """Add a generator to the plugin registry under the next stable id.

    ``block_fn(seed, stream, n[, offset]) -> uint32[n]`` must be
    traceable inside the battery's jitted programs. ``counter_based``
    is a REQUIRED declaration (RPA403): ``True`` promises exact
    continuation — ``block(n=2k) == block(n=k) ++ block(n=k, offset=k)``
    — which is what stream offsets, over-decomposition and campaign
    sub-stream grids rely on. Duplicate names are a hard error (a
    silent overwrite would re-key every checkpoint and cache digest
    that named the original). Ids are assigned in registration order
    and never reused, so a restarted daemon that re-registers the same
    generators in the same order resumes its checkpoints and ledgers."""
    _ensure_builtins()
    if name in _REGISTRY:
        raise ValueError(
            f"generator {name!r} is already registered (gen_id="
            f"{_REGISTRY[name].gen_id}); duplicate registration is a "
            f"hard error — unregister_generator first if this is a "
            f"deliberate replacement")
    row = RegisteredGenerator(name, len(_REGISTRY), block_fn,
                              bool(counter_based))
    _REGISTRY[name] = row
    GENERATORS[name] = block_fn
    GEN_IDS[name] = row.gen_id
    return row


def unregister_generator(name: str) -> None:
    """Remove the MOST RECENTLY registered generator (test teardown /
    deliberate replacement). Only the last id may be retired — ids are
    stable by construction, so popping from the middle would renumber
    every later lane and silently re-key their checkpoints."""
    if name not in _REGISTRY:
        raise KeyError(f"generator {name!r} is not registered")
    if _REGISTRY[name].gen_id != len(_REGISTRY) - 1:
        raise ValueError(
            f"generator {name!r} (gen_id={_REGISTRY[name].gen_id}) is "
            f"not the most recently registered; ids are stable — only "
            f"the last lane may be retired")
    del _REGISTRY[name]
    del GENERATORS[name]
    del GEN_IDS[name]


def registry_size() -> int:
    """Current switch width: the number of registered generators."""
    _ensure_builtins()
    return len(_REGISTRY)


def counter_based_names() -> Tuple[str, ...]:
    """Names of the offset-continuable generators, in id order — the
    DERIVED successor of the retired static ``COUNTER_BASED`` tuple."""
    _ensure_builtins()
    return tuple(r.name for r in _REGISTRY.values() if r.counter_based)


def get_generator(name: str) -> RegisteredGenerator:
    """The registry row for ``name`` (KeyError with the known set and a
    re-registration hint — an external generator must be re-registered
    before a checkpoint or ledger that names it can resume)."""
    _ensure_builtins()
    row = _REGISTRY.get(name)
    if row is None:
        raise KeyError(
            f"unknown generator {name!r}; known: {sorted(_REGISTRY)} "
            f"(an external generator must be re-registered via "
            f"register_generator before resuming work that names it)")
    return row


def switch_block(gen_id, seed, stream, n, offset=None):
    """lax.switch-able: uint32[n] block from registered lane #gen_id.

    The folded successor of ``gen_block_by_id`` with ONE offset
    convention: ``offset=None`` (canonical "no offset") traces exactly
    the offset-free branches — the classic battery hot path; anything
    else is routed as a runtime offset to every counter-based branch.
    A non-counter-based branch under an offset folds it into the stream
    id (a RESEEDED stream, not a sub-stream) purely so the switch
    traces uniformly — offset use is refused upstream by the single
    ``require_offsetable`` gate, never silently served here. The branch
    list snapshots the registry at TRACE time: generators registered
    later join the next trace (``PoolSession`` keys runners by switch
    width, so existing executables are neither used for the new lane
    nor retraced for the old ones)."""
    rows = list(_REGISTRY.values())
    if offset is None:
        fns = [functools.partial(r.block_fn, seed, stream, n)
               for r in rows]
        return jax.lax.switch(gen_id, fns)

    def _offset_fn(row):
        if row.counter_based:
            return functools.partial(row.block_fn, seed, stream, n,
                                     offset)
        u64 = functools.partial(jax.numpy.asarray, dtype=jax.numpy.uint64)
        return lambda: row.block_fn(
            seed, u64(stream) + (u64(offset) << u64(32)), n)
    return jax.lax.switch(gen_id, [_offset_fn(r) for r in rows])


# ---------------------------------------------------------------------------
# sources


class BitSource:
    """The abstract bit-supply seam every upper layer consumes.

    Contract: ``block(seed, stream, n, offset=None) -> uint32[n]`` (a
    fresh, order-independent stream per (seed, stream) pair; ``offset``
    reads words ``[offset, offset + n)`` when ``counter_based``),
    ``name`` (the short reporting key), ``uid()`` (stable identity a
    checkpoint/ledger stores and cross-checks on resume), ``digest()``
    (content identity a cache key folds in — for captured bits this is
    the FILE content, so a re-captured file misses), ``captured``
    (True routes dispatch through the prefetched-buffer path instead of
    a switch lane)."""

    name: str = ""
    counter_based: bool = False
    captured: bool = False

    def block(self, seed, stream, n, offset=None):
        """uint32[n] — words ``[offset or 0, (offset or 0) + n)`` of the
        (seed, stream) sequence."""
        raise NotImplementedError

    def uid(self) -> str:
        """Stable identity string for checkpoints/ledgers (resume
        cross-check): same source -> same uid, across processes."""
        raise NotImplementedError

    def digest(self) -> str:
        """Content identity for cache keys. Equals the pre-BitSource
        generator name for generator sources (digest stability), and a
        content hash for captured bits."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class GeneratorSource(BitSource):
    """A registered generator as a BitSource — the compiled-switch
    family. Frozen and hashable on the name alone: the registry row is
    looked up live, so a source built before ``register_generator``
    grew the registry still dispatches correctly."""
    name: str

    def __post_init__(self):
        get_generator(self.name)            # validate early, KeyError

    @property
    def gen_id(self) -> int:
        """The stable switch lane id (registry assignment order)."""
        return get_generator(self.name).gen_id

    @property
    def counter_based(self) -> bool:
        """The registry's declared offset capability for this name."""
        return get_generator(self.name).counter_based

    @property
    def captured(self) -> bool:
        """Generator sources dispatch through the compiled switch."""
        return False

    def block(self, seed, stream, n, offset=None):
        """The registered block function (traceable; ``offset=None``
        keeps the offset-free trace)."""
        fn = get_generator(self.name).block_fn
        if offset is None:
            return fn(seed, stream, n)
        return fn(seed, stream, n, offset)

    def uid(self) -> str:
        """``gen:<name>`` — algorithmic identity."""
        return f"gen:{self.name}"

    def digest(self) -> str:
        """The bare name: bitwise-compatible with every cache digest
        minted before the BitSource layer existed."""
        return self.name


class CapturedSource(BitSource):
    """External bits from a memory-mapped file, sharded by stream.

    Formats: ``.npy`` (uint32; 1-D = one stream, 2-D = (streams,
    words-per-stream)) or raw little-endian u32 (``fmt="u32"``, one
    stream). The file is mapped, never loaded: a million-word capture
    costs pages, not RAM. ``seed`` is accepted and ignored (the bits
    are what they are); ``counter_based`` is True — an offset is just a
    different read position — so captured cells take campaign
    sub-stream offsets. Reads past the captured material raise the
    typed ``CapturedBitsError`` naming the stream shard.

    ``digest()`` hashes the FILE CONTENT (cached per (size, mtime)):
    two captures of the same hardware at different times are different
    cells, and a byte-modified copy MISSES every cache entry the
    original earned."""

    def __init__(self, path: str, fmt: Optional[str] = None):
        self.path = os.path.abspath(path)
        if fmt is None:
            fmt = "npy" if self.path.endswith(".npy") else "u32"
        if fmt not in ("npy", "u32"):
            raise ValueError(f"unknown captured format {fmt!r}; "
                             f"known: ('npy', 'u32')")
        self.fmt = fmt
        if fmt == "npy":
            arr = np.load(self.path, mmap_mode="r")
        else:
            arr = np.memmap(self.path, dtype="<u4", mode="r")
        if arr.dtype != np.uint32:
            raise ValueError(
                f"captured file {path} holds {arr.dtype}, expected "
                f"uint32 words")
        if arr.ndim == 1:
            arr = arr.reshape(1, -1)
        if arr.ndim != 2:
            raise ValueError(
                f"captured file {path} has shape {arr.shape}; expected "
                f"1-D words or 2-D (streams, words)")
        self._arr = arr
        self.n_streams, self.stride = map(int, arr.shape)
        self.name = f"cap:{os.path.splitext(os.path.basename(path))[0]}"
        self._digest: Optional[str] = None

    counter_based = True
    captured = True

    def __eq__(self, other):
        return (isinstance(other, CapturedSource)
                and (self.path, self.fmt) == (other.path, other.fmt))

    def __hash__(self):
        return hash((self.path, self.fmt))

    def __repr__(self):
        return (f"CapturedSource({self.path!r}, fmt={self.fmt!r}, "
                f"streams={self.n_streams}, stride={self.stride})")

    def block(self, seed, stream, n, offset=None):
        """Words ``[offset, offset + n)`` of stream shard ``stream`` —
        a host-side mmap read (the pool prefetches these into the
        device program; they never pass through a switch lane)."""
        del seed                        # captured bits have no seed
        s, off, n = int(stream), int(offset or 0), int(n)
        if not 0 <= s < self.n_streams:
            raise CapturedBitsError(
                f"{self.name}: stream {s} out of range — the capture "
                f"holds {self.n_streams} stream shard(s)")
        if off < 0 or off + n > self.stride:
            raise CapturedBitsError(
                f"{self.name}: stream {s} read [{off}, {off + n}) "
                f"exceeds the captured {self.stride} word(s) per "
                f"stream — capture more bits or shrink the battery")
        return np.asarray(self._arr[s, off:off + n], np.uint32)

    def uid(self) -> str:
        """``cap:<stem>:<digest16>`` — identity INCLUDING content, so a
        checkpoint resumed against a re-captured file is refused."""
        return f"{self.name}:{self.digest()[:16]}"

    def digest(self) -> str:
        """sha256 of the raw file bytes (cached per (size, mtime))."""
        stat = os.stat(self.path)
        tag = (stat.st_size, stat.st_mtime_ns)
        if self._digest is None or self._digest_tag != tag:
            h = hashlib.sha256()
            with open(self.path, "rb") as f:
                for chunk in iter(lambda: f.read(1 << 20), b""):
                    h.update(chunk)
            self._digest = h.hexdigest()
            self._digest_tag = tag
        return self._digest


def resolve_source(spec: Union[BitSource, str]) -> BitSource:
    """One source from its declarative spelling: a ``BitSource`` passes
    through; ``"name"`` -> ``GeneratorSource``; ``"file:path[:fmt]"``
    -> ``CapturedSource`` (the CLI's ``--source`` grammar)."""
    if isinstance(spec, BitSource):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"source spec must be a BitSource or str, "
                        f"got {type(spec).__name__}")
    if spec.startswith("file:"):
        rest = spec[len("file:"):]
        path, sep, fmt = rest.rpartition(":")
        if not sep or os.sep in fmt or fmt not in ("npy", "u32"):
            path, fmt = rest, None
        return CapturedSource(path, fmt)
    return GeneratorSource(spec)


# ---------------------------------------------------------------------------
# capture helper (the ingest-smoke path)


def capture_generator(name: str, path: str, seed: int, n_streams: int,
                      stride: int, fmt: str = "npy") -> str:
    """Materialize a registered generator's bits as a captured file:
    stream shard s holds words ``[0, stride)`` of the generator's
    (seed, s) sequence — exactly what ``CapturedSource`` serves back,
    so a battery over the capture is bitwise the battery over the
    generator (the ingest-smoke parity assertion). Returns ``path``."""
    row = get_generator(name)
    if n_streams < 1 or stride < 1:
        raise ValueError(f"need n_streams >= 1 and stride >= 1, got "
                         f"{n_streams}, {stride}")
    with jax.experimental.enable_x64():
        shards = [np.asarray(row.block_fn(seed, s, stride), np.uint32)
                  for s in range(n_streams)]
    words = np.stack(shards)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    if fmt == "npy":
        np.save(path, words)
    elif fmt == "u32":
        if n_streams != 1:
            raise ValueError("raw u32 captures are single-stream; use "
                             "fmt='npy' for sharded captures")
        words.astype("<u4").tofile(path)
    else:
        raise ValueError(f"unknown capture format {fmt!r}")
    return path

"""Generators under test, in JAX.

Every generator exposes ``block(seed, stream, n) -> uint32[n]`` — a fresh,
order-independent stream per (seed, stream) pair. This is the TestU01-
parallel "individual test re-instantiates the generator" semantics (paper
§4.1/§11) made deterministic: job results are bitwise independent of which
worker/round executes them, which is what makes the pool's hold/release and
speculative re-execution free to reconcile.

Counter-based generators (splitmix64, threefry, pcg32/lcg64 via LCG
jump-ahead, middle-square-weyl) evaluate lanes fully in parallel. The
classic recurrences xorshift64*, RANDU and MINSTD are ALSO evaluated in
parallel via jump-ahead cycle splitting: their step maps are linear
(an affine map mod 2^64 / a multiplicative map mod 2^31 or 2^31-1 / a
GF(2)-linear map on 64 bits), so lane i computes step^i(s0) directly
with a square-and-multiply ladder of log-depth — bit-exact with the
sequential recurrence (the ``*_block_scan`` twins kept for tests and
benchmarks). Only MWC still runs as ``lax.scan``: its lag-1 carry chain
has no cheap jump. RANDU is deliberately included as a known-bad
generator the battery must flag.

64-bit integer ops require tracing under x64 (``with x64():`` —
``jax.experimental.enable_x64``); constants here are Python ints so nothing
truncates at import time. All public entry points are safe to trace inside
the battery's jitted programs.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

GOLDEN = 0x9E3779B97F4A7C15
MASK32 = 0xFFFFFFFF


def x64():
    """Context manager enabling 64-bit tracing (jax.experimental.enable_x64)."""
    return jax.experimental.enable_x64()


def _u64(x):
    return jnp.asarray(x, jnp.uint64)


def _mix_seed(seed, stream):
    return (_u64(seed) * _u64(6364136223846793005)
            + _u64(stream) * _u64(GOLDEN) + _u64(1442695040888963407))


def _hi32(x):
    return (x >> 32).astype(jnp.uint32)


# ---------------------------------------------------------------------------
# counter-based

def _splitmix_hash(z):
    z = (z + _u64(GOLDEN))
    z = (z ^ (z >> 30)) * _u64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> 27)) * _u64(0x94D049BB133111EB)
    return z ^ (z >> 31)


def splitmix64_block(seed, stream, n, offset=0):
    base = _mix_seed(seed, stream)
    ctr = (jnp.arange(n, dtype=jnp.uint64) + _u64(offset)) * _u64(GOLDEN) + base
    return _hi32(_splitmix_hash(ctr))


def msweyl_block(seed, stream, n, offset=0):
    """Middle-Square Weyl sequence (Widynski) — counter form."""
    s = _mix_seed(seed, stream) | _u64(1)
    w = (jnp.arange(1, n + 1, dtype=jnp.uint64) + _u64(offset)) * s
    x = w
    for _ in range(3):
        x = x * x + w
        x = (x >> 32) | (x << 32)
    return _hi32(x)


def threefry_block(seed, stream, n, offset=0):
    """Threefry in explicit counter mode: word i is
    ``bits(fold_in(fold_in(key, hi32(c)), lo32(c)))`` for the 64-bit
    counter ``c = offset + i``, one key-hash chain per element, vmapped.
    jax.random.bits over a whole shape is NOT continuation-stable (its
    threefry2x32 pairs the iota's halves, so the pairing depends on the
    block length) — hashing each counter independently is, at a small
    constant factor in hashing cost. The counter is folded as two
    32-bit halves because ``fold_in`` takes 32-bit data: a single
    truncated fold would silently wrap past 2^32 words and alias
    distant campaign sub-streams (exactly the overlap the pairstream
    check exists to rule out)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), stream)
    ctr = jnp.arange(n, dtype=jnp.uint64) + _u64(offset)
    hi = (ctr >> 32).astype(jnp.uint32)
    lo = (ctr & _u64(MASK32)).astype(jnp.uint32)
    return jax.vmap(lambda h, l: jax.random.bits(
        jax.random.fold_in(jax.random.fold_in(key, h), l), (),
        jnp.uint32))(hi, lo)


LCG_A = 6364136223846793005
LCG_C = 1442695040888963407


def _lcg_jump(s0, idx):
    """state_i = A^i s0 + C (A^i-1)/(A-1), per lane in O(64) steps."""
    a_acc = jnp.ones_like(idx)
    c_acc = jnp.zeros_like(idx)
    a_pow = jnp.broadcast_to(_u64(LCG_A), idx.shape)
    c_pow = jnp.broadcast_to(_u64(LCG_C), idx.shape)
    for bit in range(64):
        take = ((idx >> bit) & 1) == 1
        c_acc = jnp.where(take, c_acc * a_pow + c_pow, c_acc)
        a_acc = jnp.where(take, a_acc * a_pow, a_acc)
        c_pow = c_pow * (a_pow + 1)
        a_pow = a_pow * a_pow
    return a_acc * s0 + c_acc


def pcg32_block(seed, stream, n, offset=0):
    """PCG-XSH-RR 64/32 with per-lane LCG jump-ahead."""
    st = _lcg_jump(_mix_seed(seed, stream),
                   jnp.arange(n, dtype=jnp.uint64) + _u64(offset))
    xorshifted = (((st >> 18) ^ st) >> 27).astype(jnp.uint32)
    rot = (st >> 59).astype(jnp.uint32)
    return (xorshifted >> rot) | (xorshifted << ((-rot) & jnp.uint32(31)))


def lcg64_block(seed, stream, n, offset=0):
    st = _lcg_jump(_mix_seed(seed, stream),
                   jnp.arange(n, dtype=jnp.uint64) + _u64(offset))
    return _hi32(st)


# ---------------------------------------------------------------------------
# jump-ahead cycle splitting (log-depth twins of the classic recurrences)

def _jump_bits(n, offset):
    """Ladder length: enough exponent bits to cover every lane index
    ``1..n+offset``. Static when offset is a Python int (the battery hot
    path); a traced offset falls back to the full 64-bit ladder."""
    if isinstance(offset, (int, np.integer)):
        return max(int(int(n) + int(offset)).bit_length(), 1)
    return 64


def _pow_jump(idx, mult, nbits, mulmod):
    """``mult^idx`` per lane by square-and-multiply — the ``_lcg_jump``
    ladder generalized to any associative product ``mulmod``."""
    acc = jnp.ones_like(idx)
    apow = jnp.broadcast_to(_u64(mult), idx.shape)
    for bit in range(nbits):
        take = ((idx >> bit) & 1) == 1
        acc = jnp.where(take, mulmod(acc, apow), acc)
        apow = mulmod(apow, apow)
    return acc


@functools.lru_cache(maxsize=1)
def _xs_jump_cols():
    """Columns of M^(2^k), k = 0..63, for the xorshift64 step matrix M
    (the 12/25/27 shift-XOR map is linear over GF(2)^64). Host-side
    precompute: column b of M is step(e_b); squaring applies the current
    power to each of its own columns (matvec = XOR of selected columns)."""
    mask = (1 << 64) - 1
    cols = []
    for b in range(64):
        s = 1 << b
        s ^= s >> 12
        s ^= (s << 25) & mask
        s ^= s >> 27
        cols.append(s)
    cols = np.array(cols, np.uint64)
    powers = np.empty((64, 64), np.uint64)
    for k in range(64):
        powers[k] = cols
        nxt = np.zeros(64, np.uint64)
        for j in range(64):
            bit = ((cols >> np.uint64(j)) & np.uint64(1)).astype(bool)
            nxt = np.where(bit, nxt ^ cols[j], nxt)
        cols = nxt
    return powers


def _xs_jump(s0, idx, nbits):
    """``M^idx s0`` per lane: GF(2) square-and-multiply over the
    precomputed matrix powers, O(64 log idx) depth instead of an O(idx)
    scan. The matvec is an XOR-reduce of the state-selected columns
    (one (lanes, 64) reduce per ladder step keeps the trace small; XOR
    is exact, so bit-exactness vs the scan twin is preserved)."""
    pows = _xs_jump_cols()
    s = jnp.broadcast_to(s0, idx.shape)
    bitpos = jnp.arange(64, dtype=jnp.uint64)
    for k in range(nbits):
        take = ((idx >> k) & 1) == 1
        cols = jnp.asarray(pows[k])
        sel = jnp.where(((s[:, None] >> bitpos[None, :]) & 1) == 1,
                        cols[None, :], _u64(0))
        y = jax.lax.reduce(sel, _u64(0), jax.lax.bitwise_xor, (1,))
        s = jnp.where(take, y, s)
    return s


# xorshift cycle-split chunk: each lane jump-starts its segment with the
# GF(2) ladder, then steps XS_CHUNK times — the ladder (the expensive 64-
# column matvec) runs once per CHUNK outputs instead of once per output,
# and the residual sequential depth is a constant 64, not O(n)
XS_CHUNK = 64


def xorshift64s_block(seed, stream, n, offset=0):
    """xorshift64* via jump-ahead cycle splitting: lane l jumps directly
    to state M^(l*CHUNK+offset) s0 (log-depth GF(2) ladder), then a
    vmapped constant-length micro-scan emits its segment. Bit-exact with
    the sequential recurrence (``xorshift64s_block_scan``)."""
    s0 = _mix_seed(seed, stream) | _u64(1)
    lanes = -(-n // XS_CHUNK)
    starts = (jnp.arange(lanes, dtype=jnp.uint64) * XS_CHUNK
              + _u64(offset))
    lane0 = _xs_jump(s0, starts, _jump_bits(n, offset))

    def step(s, _):
        s = s ^ (s >> 12)
        s = s ^ (s << 25)
        s = s ^ (s >> 27)
        return s, s

    def segment(st):
        _, outs = jax.lax.scan(step, st, None, length=XS_CHUNK)
        return outs

    states = jax.vmap(segment)(lane0).reshape(-1)[:n]
    return _hi32(states * _u64(0x2545F4914F6CDD1D))


def randu_block(seed, stream, n, offset=0):
    """RANDU: x <- 65539 x mod 2^31, via multiplicative jump-ahead
    (x_i = 65539^i x_0 — the modulus is a power of two, so the ring
    product is a masked multiply). Famously defective — the battery's
    canary (must FAIL spectral-sensitive tests)."""
    s0 = (_mix_seed(seed, stream) & _u64(0x7FFFFFFF)) | _u64(1)
    idx = jnp.arange(1, n + 1, dtype=jnp.uint64) + _u64(offset)

    def mm(a, b):
        return (a * b) & _u64(0x7FFFFFFF)
    st = mm(jnp.broadcast_to(s0, idx.shape),
            _pow_jump(idx, 65539, _jump_bits(n, offset), mm))
    return (st << 1).astype(jnp.uint32)


def minstd_block(seed, stream, n, offset=0):
    """MINSTD: x <- 16807 x mod (2^31 - 1), via multiplicative jump-ahead
    (prime modulus; 62-bit products fit uint64)."""
    s0 = (_mix_seed(seed, stream) % _u64(2147483646)) + _u64(1)
    idx = jnp.arange(1, n + 1, dtype=jnp.uint64) + _u64(offset)

    def mm(a, b):
        return (a * b) % _u64(2147483647)
    st = mm(jnp.broadcast_to(s0, idx.shape),
            _pow_jump(idx, 16807, _jump_bits(n, offset), mm))
    return (st << 1).astype(jnp.uint32)


# ---------------------------------------------------------------------------
# sequential recurrences

def _scan_block(step, state0, n):
    def body(st, _):
        return step(st)
    _, outs = jax.lax.scan(body, state0, None, length=n)
    return outs


def xorshift64s_block_scan(seed, stream, n):
    """The O(n)-sequential twin of ``xorshift64s_block`` (tests assert the
    jump path is bit-exact against this)."""
    def step(s):
        s = s ^ (s >> 12)
        s = s ^ (s << 25)
        s = s ^ (s >> 27)
        return s, _hi32(s * _u64(0x2545F4914F6CDD1D))
    return _scan_block(step, _mix_seed(seed, stream) | _u64(1), n)


def mwc_block(seed, stream, n):
    """Multiply-with-carry (Marsaglia), 32-bit lag-1. The ONLY generator
    still evaluated as a sequential ``lax.scan`` — the carry chain is not
    linear in any cheap ring, so there is no O(1) jump-ahead; it is the
    lone member of ``COUNTER_BASED``'s complement and does not accept an
    ``offset``."""
    s = _mix_seed(seed, stream)
    x0 = (s >> 32) | _u64(1)
    c0 = (s & _u64(MASK32)) | _u64(1)

    def step(st):
        x, c = st
        t = _u64(4294957665) * (x & _u64(MASK32)) + c
        return (t & _u64(MASK32), t >> 32), (t & _u64(MASK32)).astype(jnp.uint32)
    return _scan_block(step, (x0, c0), n)


def randu_block_scan(seed, stream, n):
    """Sequential twin of ``randu_block`` (bit-exactness reference)."""
    s0 = (_mix_seed(seed, stream) & _u64(0x7FFFFFFF)) | _u64(1)

    def step(s):
        s = (s * _u64(65539)) & _u64(0x7FFFFFFF)
        return s, (s << 1).astype(jnp.uint32)
    return _scan_block(step, s0, n)


def minstd_block_scan(seed, stream, n):
    """Sequential twin of ``minstd_block`` (bit-exactness reference)."""
    def step(s):
        s = (s * _u64(16807)) % _u64(2147483647)
        return s, (s << 1).astype(jnp.uint32)
    s0 = (_mix_seed(seed, stream) % _u64(2147483646)) + _u64(1)
    return _scan_block(step, s0, n)


# sequential references for the jump-ahead generators, keyed by name —
# what tests/test_backends.py asserts bit-exactness against
SCAN_REFERENCE: Dict[str, Callable] = {
    "xorshift64s": xorshift64s_block_scan,
    "randu": randu_block_scan,
    "minstd": minstd_block_scan,
}


# ---------------------------------------------------------------------------
# the plugin registry (rng/sources.py): built-ins register here, in the
# historical dict order so their stable gen_ids match every checkpoint,
# ledger and cache digest minted before the BitSource layer existed.
# GENERATORS / GEN_IDS are re-exported LIVE views (the same dict objects
# sources.py mutates on register/unregister); gen_block_by_id is the
# registry-backed switch. Counter-based: block(seed, stream, n, offset)
# supports exact continuation — block(n=2k) == block(n=k) ++
# block(n=k, offset=k) — the property that makes sequential-reuse mode
# and over-decomposition exact. xorshift64s/randu/minstd joined via
# jump-ahead cycle splitting; mwc's lag-1 carry chain has no cheap jump,
# stays a sequential lax.scan, and takes no offset.

from repro.rng.sources import (  # noqa: E402
    GENERATORS,
    GEN_IDS,
    register_generator,
    switch_block as gen_block_by_id,
)

register_generator("splitmix64", splitmix64_block, counter_based=True)
register_generator("msweyl", msweyl_block, counter_based=True)
register_generator("threefry", threefry_block, counter_based=True)
register_generator("pcg32", pcg32_block, counter_based=True)
register_generator("lcg64", lcg64_block, counter_based=True)
register_generator("xorshift64s", xorshift64s_block, counter_based=True)
register_generator("mwc", mwc_block, counter_based=False)
register_generator("randu", randu_block, counter_based=True)
register_generator("minstd", minstd_block, counter_based=True)


def __getattr__(name):
    """``COUNTER_BASED`` is DERIVED from the live registry (PEP 562):
    the static tuple is retired so a runtime-registered generator's
    declared capability is visible everywhere the old constant was
    consulted, with no second source of truth to fall stale."""
    if name == "COUNTER_BASED":
        from repro.rng.sources import counter_based_names
        return counter_based_names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ---------------------------------------------------------------------------
# campaign stream grids (cycle splitting at the block level)

def stream_offsets(n_streams: int, span: int) -> np.ndarray:
    """Word offsets of ``n_streams`` disjoint parallel sub-streams spaced
    ``span`` words apart: stream s owns ``[s * span, (s + 1) * span)`` of
    every (seed, stream-id) sequence. With ``span >= `` the widest block
    any battery job reads, cells of a campaign grid consume disjoint
    words by construction — the modern analogue of the paper's "one
    generator per idle machine" is "one sub-stream per grid cell"."""
    if n_streams < 1:
        raise ValueError(f"n_streams must be >= 1, got {n_streams}")
    if span < 1:
        raise ValueError(
            f"span must be >= 1, got {span}: a zero or negative span "
            f"would hand every stream overlapping (or wrapped) words")
    last = (n_streams - 1) * span            # exact Python-int arithmetic
    if last > np.iinfo(np.int64).max:
        raise ValueError(
            f"stream {n_streams - 1} offset {last} overflows int64 "
            f"words; shrink span ({span}) or n_streams ({n_streams})")
    return np.arange(n_streams, dtype=np.int64) * np.int64(span)


def seam_offsets(n_streams: int, span: int, n_words: int) -> np.ndarray:
    """Block offsets straddling each adjacent-stream SEAM: pair s reads
    ``[(s+1)*span - n_words, (s+1)*span + n_words)`` — the last
    ``n_words`` words of stream s followed by the first ``n_words`` of
    stream s+1. A ``pairstream`` kernel splits that block in half and
    checks the halves are uncorrelated and disjoint, which is exactly
    where an off-by-one in the jump-ahead offset arithmetic would show
    up (overlapping or correlated words across the seam)."""
    if n_streams < 2:
        return np.zeros((0,), np.int64)
    if span < 1:
        raise ValueError(
            f"span must be >= 1, got {span}: a zero or negative span "
            f"would place stream 1's seam at or before word 0 and wrap")
    if n_words < 1:
        raise ValueError(f"n_words must be >= 1, got {n_words}")
    if n_words > span:
        raise ValueError(
            f"seam block of {n_words} words needs span >= n_words, "
            f"got span={span}")
    hi = (n_streams - 1) * span + n_words    # exact Python-int arithmetic
    if hi > np.iinfo(np.int64).max:
        raise ValueError(
            f"seam {n_streams - 2} (streams {n_streams - 2}|"
            f"{n_streams - 1}) reads up to word {hi}, which overflows "
            f"int64; shrink span ({span}) or n_streams ({n_streams})")
    seams = np.arange(1, n_streams, dtype=np.int64) * np.int64(span)
    return seams - np.int64(n_words)


def to_unit(bits):
    """uint32 -> float32 in [0, 1)."""
    return (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))

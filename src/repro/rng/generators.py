"""Generators under test, in JAX.

Every generator exposes ``block(seed, stream, n) -> uint32[n]`` — a fresh,
order-independent stream per (seed, stream) pair. This is the TestU01-
parallel "individual test re-instantiates the generator" semantics (paper
§4.1/§11) made deterministic: job results are bitwise independent of which
worker/round executes them, which is what makes the pool's hold/release and
speculative re-execution free to reconcile.

Counter-based generators (splitmix64, threefry, pcg32/lcg64 via LCG
jump-ahead, middle-square-weyl) evaluate lanes fully in parallel; classic
sequential recurrences (xorshift64*, MWC, RANDU, MINSTD) run as ``lax.scan``.
RANDU is deliberately included as a known-bad generator the battery must
flag.

64-bit integer ops require tracing under x64 (``with x64():`` —
``jax.experimental.enable_x64``); constants here are Python ints so nothing
truncates at import time. All public entry points are safe to trace inside
the battery's jitted programs.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

GOLDEN = 0x9E3779B97F4A7C15
MASK32 = 0xFFFFFFFF


def x64():
    """Context manager enabling 64-bit tracing (jax.experimental.enable_x64)."""
    return jax.experimental.enable_x64()


def _u64(x):
    return jnp.asarray(x, jnp.uint64)


def _mix_seed(seed, stream):
    return (_u64(seed) * _u64(6364136223846793005)
            + _u64(stream) * _u64(GOLDEN) + _u64(1442695040888963407))


def _hi32(x):
    return (x >> 32).astype(jnp.uint32)


# ---------------------------------------------------------------------------
# counter-based

def _splitmix_hash(z):
    z = (z + _u64(GOLDEN))
    z = (z ^ (z >> 30)) * _u64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> 27)) * _u64(0x94D049BB133111EB)
    return z ^ (z >> 31)


def splitmix64_block(seed, stream, n, offset=0):
    base = _mix_seed(seed, stream)
    ctr = (jnp.arange(n, dtype=jnp.uint64) + _u64(offset)) * _u64(GOLDEN) + base
    return _hi32(_splitmix_hash(ctr))


def msweyl_block(seed, stream, n, offset=0):
    """Middle-Square Weyl sequence (Widynski) — counter form."""
    s = _mix_seed(seed, stream) | _u64(1)
    w = (jnp.arange(1, n + 1, dtype=jnp.uint64) + _u64(offset)) * s
    x = w
    for _ in range(3):
        x = x * x + w
        x = (x >> 32) | (x << 32)
    return _hi32(x)


def threefry_block(seed, stream, n, offset=0):
    """Threefry in explicit counter mode: word i is
    ``bits(fold_in(key, offset + i))``, one key-hash per element, vmapped.
    jax.random.bits over a whole shape is NOT continuation-stable (its
    threefry2x32 pairs the iota's halves, so the pairing depends on the
    block length) — hashing each counter independently is, at ~2x the
    hashing cost."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), stream)
    ctr = jnp.arange(n, dtype=jnp.uint32) + jnp.uint32(offset)
    return jax.vmap(lambda i: jax.random.bits(
        jax.random.fold_in(key, i), (), jnp.uint32))(ctr)


LCG_A = 6364136223846793005
LCG_C = 1442695040888963407


def _lcg_jump(s0, idx):
    """state_i = A^i s0 + C (A^i-1)/(A-1), per lane in O(64) steps."""
    a_acc = jnp.ones_like(idx)
    c_acc = jnp.zeros_like(idx)
    a_pow = jnp.broadcast_to(_u64(LCG_A), idx.shape)
    c_pow = jnp.broadcast_to(_u64(LCG_C), idx.shape)
    for bit in range(64):
        take = ((idx >> bit) & 1) == 1
        c_acc = jnp.where(take, c_acc * a_pow + c_pow, c_acc)
        a_acc = jnp.where(take, a_acc * a_pow, a_acc)
        c_pow = c_pow * (a_pow + 1)
        a_pow = a_pow * a_pow
    return a_acc * s0 + c_acc


def pcg32_block(seed, stream, n, offset=0):
    """PCG-XSH-RR 64/32 with per-lane LCG jump-ahead."""
    st = _lcg_jump(_mix_seed(seed, stream),
                   jnp.arange(n, dtype=jnp.uint64) + _u64(offset))
    xorshifted = (((st >> 18) ^ st) >> 27).astype(jnp.uint32)
    rot = (st >> 59).astype(jnp.uint32)
    return (xorshifted >> rot) | (xorshifted << ((-rot) & jnp.uint32(31)))


def lcg64_block(seed, stream, n, offset=0):
    st = _lcg_jump(_mix_seed(seed, stream),
                   jnp.arange(n, dtype=jnp.uint64) + _u64(offset))
    return _hi32(st)


# ---------------------------------------------------------------------------
# sequential recurrences

def _scan_block(step, state0, n):
    def body(st, _):
        return step(st)
    _, outs = jax.lax.scan(body, state0, None, length=n)
    return outs


def xorshift64s_block(seed, stream, n):
    def step(s):
        s = s ^ (s >> 12)
        s = s ^ (s << 25)
        s = s ^ (s >> 27)
        return s, _hi32(s * _u64(0x2545F4914F6CDD1D))
    return _scan_block(step, _mix_seed(seed, stream) | _u64(1), n)


def mwc_block(seed, stream, n):
    """Multiply-with-carry (Marsaglia), 32-bit lag-1."""
    s = _mix_seed(seed, stream)
    x0 = (s >> 32) | _u64(1)
    c0 = (s & _u64(MASK32)) | _u64(1)

    def step(st):
        x, c = st
        t = _u64(4294957665) * (x & _u64(MASK32)) + c
        return (t & _u64(MASK32), t >> 32), (t & _u64(MASK32)).astype(jnp.uint32)
    return _scan_block(step, (x0, c0), n)


def randu_block(seed, stream, n):
    """RANDU: x <- 65539 x mod 2^31. Famously defective — the battery's
    canary (must FAIL spectral-sensitive tests)."""
    s0 = (_mix_seed(seed, stream) & _u64(0x7FFFFFFF)) | _u64(1)

    def step(s):
        s = (s * _u64(65539)) & _u64(0x7FFFFFFF)
        return s, (s << 1).astype(jnp.uint32)
    return _scan_block(step, s0, n)


def minstd_block(seed, stream, n):
    """MINSTD: x <- 16807 x mod (2^31 - 1)."""
    def step(s):
        s = (s * _u64(16807)) % _u64(2147483647)
        return s, (s << 1).astype(jnp.uint32)
    s0 = (_mix_seed(seed, stream) % _u64(2147483646)) + _u64(1)
    return _scan_block(step, s0, n)


GENERATORS: Dict[str, Callable] = {
    "splitmix64": splitmix64_block,
    "msweyl": msweyl_block,
    "threefry": threefry_block,
    "pcg32": pcg32_block,
    "lcg64": lcg64_block,
    "xorshift64s": xorshift64s_block,
    "mwc": mwc_block,
    "randu": randu_block,
    "minstd": minstd_block,
}
GEN_IDS = {name: i for i, name in enumerate(GENERATORS)}

# Counter-based generators: block(seed, stream, n, offset) supports exact
# continuation — block(n=2k) == block(n=k) ++ block(n=k, offset=k) — the
# property that makes sequential-reuse mode and over-decomposition exact.
# The scan-based recurrences (xorshift64s, mwc, randu, minstd) are absent
# by construction: they have no O(1) jump-ahead.
COUNTER_BASED = ("splitmix64", "msweyl", "threefry", "pcg32", "lcg64")


def gen_block_by_id(gen_id, seed, stream, n):
    """lax.switch-able: uint32[n] block from generator #gen_id."""
    fns = [functools.partial(g, seed, stream, n) for g in GENERATORS.values()]
    return jax.lax.switch(gen_id, fns)


def to_unit(bits):
    """uint32 -> float32 in [0, 1)."""
    return (bits >> 8).astype(jnp.float32) * (1.0 / (1 << 24))

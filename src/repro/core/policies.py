"""Schedule + retry policies for the battery pool.

The paper's `makesub` hard-codes one placement (round-robin over the
condor slot list). Here placement is a registered ``SchedulePolicy``:

  roundrobin      the paper's batch model — ceil(K/W) batches (§11)
  lpt             longest-processing-time first; strictly better makespan
                  whenever test costs are skewed (TestU01's are)
  over_decompose  straggler mitigation at plan level: the heaviest tests'
                  sample ranges are split into sub-jobs (fresh sub-streams,
                  lambda-invariant re-parameterization), scheduled with LPT,
                  and the stitcher folds each group's sub-results back into
                  one verdict via a Stouffer/Fisher p-value combine.
  adaptive        early-stopping order (Ryabko-style, DESIGN.md §3): rounds
                  are filled in descending discrimination/cost priority, so
                  the cheap tests that historically kill bad generators run
                  first and the sequential verdict engine (stitch) can
                  cancel a failed generator after round one instead of
                  after the whole battery.

Policies are host-side and pure: ``plan`` maps (costs, workers) to a
``Plan``; ``decompose`` (optional) maps the battery's job table to an
expanded one; ``plan_entries`` (optional, adaptive only) is preferred by
the driver when the policy needs more than costs — the battery entries
carry the kernel family the discrimination table is keyed on. Only
decomposition changes the compiled pool program, so ``PoolSession`` keys
its compile cache on the decomposition signature, not the plan mode.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Protocol, Sequence, Union, runtime_checkable

import numpy as np


# ---------------------------------------------------------------------------
# plan


@dataclasses.dataclass(frozen=True)
class Plan:
    """A placement: (rounds, workers) job-index grid plus the makespan
    estimates the policies compete on."""
    assignment: np.ndarray          # (rounds, workers) int32 job index, -1 idle
    mode: str
    est_makespan: float             # sum over rounds of max worker cost
    est_ideal: float                # sum(costs)/W lower bound

    @property
    def rounds(self) -> int:
        """Batches the plan dispatches (the paper's ceil(K/W))."""
        return self.assignment.shape[0]


def _ordered_assignment(order, n_workers: int) -> np.ndarray:
    """Fill rounds of W slots in the given job order (round-robin is the
    identity order)."""
    order = list(order)
    rounds = -(-len(order) // n_workers)
    a = np.full((rounds, n_workers), -1, np.int32)
    for pos, i in enumerate(order):
        a[pos // n_workers, pos % n_workers] = i
    return a


def _roundrobin_plan(costs: np.ndarray, n_workers: int) -> np.ndarray:
    return _ordered_assignment(range(len(costs)), n_workers)


def _lpt_plan(costs: np.ndarray, n_workers: int) -> np.ndarray:
    order = np.argsort(-costs)
    loads = np.zeros(n_workers)
    lists: List[List[int]] = [[] for _ in range(n_workers)]
    for i in order:
        w = int(np.argmin(loads))
        loads[w] += costs[i]
        lists[w].append(int(i))
    rounds = max(len(l) for l in lists)
    a = np.full((rounds, n_workers), -1, np.int32)
    for w, l in enumerate(lists):
        for r, i in enumerate(l):
            a[r, w] = i
    return a


def _finish_plan(a: np.ndarray, costs: np.ndarray, n_workers: int,
                 mode: str) -> Plan:
    per_round = np.where(a >= 0, costs[np.clip(a, 0, None)], 0.0)
    est = float(per_round.max(axis=1).sum())
    return Plan(a, mode, est, float(costs.sum() / n_workers))


# ---------------------------------------------------------------------------
# policy protocol + registry


@runtime_checkable
class SchedulePolicy(Protocol):
    """Placement strategy. ``decompose`` returning None means the job
    table is the battery's entry list unchanged.

    ``decompose`` must be a pure function of the battery: the session
    invokes it with ``n_workers=None`` (the argument survives for
    signature compatibility only), because one job table serves every
    pool width — job ids, sub-stream assignments and checkpoints all
    have to survive elastic re-meshing (DESIGN.md §6). Width-aware
    placement belongs in ``plan``, which does get ``n_workers``."""
    name: str

    def plan(self, costs: Sequence[float], n_workers: int) -> Plan:
        """Place jobs with the given costs onto ``n_workers`` slots."""
        ...

    def decompose(self, entries, n_workers: Optional[int] = None
                  ) -> Optional[list]:
        """Optionally expand the battery into sub-jobs (None = as-is)."""
        ...

    def signature(self) -> Optional[tuple]:
        """Compile-cache key component: None unless decomposition changes
        the compiled job table."""
        ...


@dataclasses.dataclass(frozen=True)
class RoundRobinPolicy:
    """The paper's placement: fill rounds in battery order (§11's
    ceil(K/W) batch model, reproduced exactly)."""
    name: str = "roundrobin"

    def plan(self, costs, n_workers):
        """Identity-order round fill."""
        costs = np.asarray(costs, np.float64)
        return _finish_plan(_roundrobin_plan(costs, n_workers), costs,
                            n_workers, self.name)

    def decompose(self, entries, n_workers):
        """Never decomposes."""
        return None

    def signature(self):
        """No decomposition -> no compile-cache component."""
        return None


@dataclasses.dataclass(frozen=True)
class LPTPolicy:
    """Longest-processing-time-first: strictly better makespan than
    round-robin whenever test costs are skewed (TestU01's are)."""
    name: str = "lpt"

    def plan(self, costs, n_workers):
        """Greedy LPT onto the least-loaded worker."""
        costs = np.asarray(costs, np.float64)
        return _finish_plan(_lpt_plan(costs, n_workers), costs, n_workers,
                            self.name)

    def decompose(self, entries, n_workers):
        """Never decomposes."""
        return None

    def signature(self):
        """No decomposition -> no compile-cache component."""
        return None


@dataclasses.dataclass(frozen=True)
class OverDecomposePolicy:
    """Split any test whose cost exceeds ``threshold`` x the battery's mean
    test cost into up to ``max_parts`` sub-jobs, then LPT-pack the expanded
    table. The cut is deliberately a function of the battery alone (NOT of
    ``n_workers``): the job table — and with it checkpoint job indices and
    sub-stream ids — stays identical across mesh widths, so a checkpointed
    run resumes correctly after elastic re-meshing. Sub-jobs draw fresh,
    disjoint generator sub-streams and are re-parameterized
    lambda-invariantly (see battery.split_entry), so each sub-result is a
    valid p-value; the stitcher combines a group's sub-p-values with
    ``combine`` ('stouffer' keeps both tails, 'fisher' is small-p
    sensitive)."""
    name: str = "over_decompose"
    max_parts: int = 8
    threshold: float = 1.0
    combine: str = "stouffer"

    def plan(self, costs, n_workers):
        """LPT over the (already expanded) job table."""
        costs = np.asarray(costs, np.float64)
        return _finish_plan(_lpt_plan(costs, n_workers), costs, n_workers,
                            self.name)

    def decompose(self, entries, n_workers=None):
        """Split over-threshold tests into lambda-invariant sub-jobs
        (see the class docstring; None when nothing splits)."""
        from repro.core.battery import split_entry
        if not entries:                         # replan of nothing: no table
            return None
        costs = np.asarray([e.cost for e in entries], np.float64)
        cut = self.threshold * max(float(costs.mean()), 1e-12)
        jobs = []
        for e in entries:
            parts = 1
            if e.cost > cut:
                parts = min(self.max_parts, max(int(np.ceil(e.cost / cut)), 2))
            subs = split_entry(e, parts, start_index=len(jobs))
            jobs.extend(subs)
        if len(jobs) == len(entries):           # nothing split
            return None
        return jobs

    def signature(self):
        """The decomposition parameters ARE the compiled-table identity."""
        return (self.name, self.max_parts, self.threshold)


def _ordered_plan(order: Sequence[int], costs: np.ndarray,
                  n_workers: int, mode: str) -> Plan:
    """Priority-ordered plan. Round r IS the r-th interim look of the
    sequential verdict engine, so order here is execution order, not
    just placement."""
    return _finish_plan(_ordered_assignment(order, n_workers), costs,
                        n_workers, mode)


@dataclasses.dataclass(frozen=True)
class AdaptivePolicy:
    """Early-stopping schedule order: jobs are ranked by
    ``discrimination / cost`` (battery.DISCRIMINATION — the static table
    seeded from the known-bad generators) and rounds are filled in that
    order, so the cheapest historically-discriminating tests execute in
    the earliest rounds. Ties and unknown kernels fall back to
    cheapest-first, which still front-loads verdict information: an
    interim look after round r has seen the most tests per unit of wall
    clock. Placement is deliberately NOT makespan-optimal — the point is
    to minimise expected rounds-to-verdict for a bad generator, and the
    driver cancels the tail of the plan once the verdict lands."""
    name: str = "adaptive"

    def plan(self, costs, n_workers):
        """Cost-only fallback order (cheapest first)."""
        costs = np.asarray(costs, np.float64)
        order = np.argsort(costs, kind="stable")        # cheap first
        return _ordered_plan([int(i) for i in order], costs, n_workers,
                             self.name)

    def plan_entries(self, entries, n_workers):
        """Priority plan over real battery entries (discrimination/cost)."""
        from repro.core.battery import discrimination
        costs = np.asarray([e.cost for e in entries], np.float64)
        score = np.asarray([discrimination(e) for e in entries], np.float64)
        # primary: discrimination per unit cost, descending; tie-break on
        # cheapness so zero-discrimination tails still run cheap-first
        priority = score / np.maximum(costs, 1e-12)
        order = sorted(range(len(entries)),
                       key=lambda i: (-priority[i], costs[i], i))
        return _ordered_plan(order, costs, n_workers, self.name)

    def decompose(self, entries, n_workers):
        """Never decomposes."""
        return None

    def signature(self):
        """No decomposition -> no compile-cache component."""
        return None


POLICIES: Dict[str, SchedulePolicy] = {}


def register_policy(policy: SchedulePolicy) -> SchedulePolicy:
    """Add a policy to the registry under ``policy.name`` (last wins)."""
    POLICIES[policy.name] = policy
    return policy


register_policy(RoundRobinPolicy())
register_policy(LPTPolicy())
register_policy(OverDecomposePolicy())
register_policy(AdaptivePolicy())


def get_policy(policy: Union[str, SchedulePolicy]) -> SchedulePolicy:
    """Resolve a mode string (or pass a policy object through)."""
    if isinstance(policy, str):
        try:
            return POLICIES[policy]
        except KeyError:
            raise ValueError(
                f"unknown schedule policy {policy!r}; "
                f"registered: {sorted(POLICIES)}") from None
    if isinstance(policy, SchedulePolicy):
        return policy
    raise TypeError(f"not a SchedulePolicy: {policy!r}")


# ---------------------------------------------------------------------------
# retry


class RetryBudgetExhausted(RuntimeError):
    """The driver's release budget ran out with jobs still HELD.

    Raised by ``BatteryRun.drive``/``stream`` (and re-raised by
    ``serve.Ticket.result`` for failed tickets) instead of silently
    finalising with missing results.  Carries the final HELD job-id
    list so callers can report or replan; catch it and call
    ``_finalize`` explicitly if a partial report is genuinely wanted.
    """

    def __init__(self, held: Sequence[int], retries: int):
        """Record the unrecoverable job ids and the budget that was spent."""
        self.held = [int(j) for j in held]
        self.retries = int(retries)
        super().__init__(
            f"retry budget exhausted after {self.retries} release "
            f"pass(es) with {len(self.held)} job(s) still HELD: "
            f"{self.held}")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """hold/release discipline: how many release passes the driver grants
    before exhaustion is reported as :class:`RetryBudgetExhausted`
    (paper: condor_release), plus the robustness knobs of DESIGN.md §12 —
    exponential backoff between release passes, a per-round straggler
    ``deadline``, and the consecutive-fault ``quarantine_after``
    threshold for flaky worker slots."""
    max_retries: int = 2
    backoff_base: float = 0.0      # seconds before the first release; 0 = off
    backoff_mult: float = 2.0      # exponential growth per release pass
    backoff_max: float = 60.0      # hard cap, jitter included
    deadline: Optional[float] = None      # per-round seconds before HELD
    quarantine_after: Optional[int] = None  # consecutive faults per slot

    def __post_init__(self):
        """Reject nonsense budgets up front instead of failing silently."""
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base < 0:
            raise ValueError(
                f"backoff_base must be >= 0, got {self.backoff_base}")
        if self.backoff_mult < 1.0:
            raise ValueError(
                f"backoff_mult must be >= 1, got {self.backoff_mult}")
        if self.backoff_max < 0:
            raise ValueError(
                f"backoff_max must be >= 0, got {self.backoff_max}")
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError(
                f"deadline must be > 0 seconds, got {self.deadline}")
        if self.quarantine_after is not None and self.quarantine_after < 1:
            raise ValueError(
                f"quarantine_after must be >= 1, got {self.quarantine_after}")

    def backoff_for(self, attempt: int) -> float:
        """Seconds to sleep before driver release pass ``attempt`` (0-based).

        Exponential (``base * mult**attempt``) with up to 10%
        deterministic jitter — the jitter is a sha256 hash of the
        attempt index, not a random draw, so replays are bit-for-bit —
        clamped to ``backoff_max``.  Returns 0.0 when backoff is off
        (the default), which keeps pre-existing drive loops sleepless.
        """
        if self.backoff_base <= 0:
            return 0.0
        raw = self.backoff_base * self.backoff_mult ** max(int(attempt), 0)
        h = hashlib.sha256(f"backoff:{int(attempt)}".encode()).digest()
        jitter = 1.0 + 0.1 * (int.from_bytes(h[:4], "big") / 2.0 ** 32)
        return min(self.backoff_max, raw * jitter)

"""Generator-fleet screening campaigns (DESIGN.md §8).

The paper turned ONE five-hour battery into a fleet of small jobs on
idle machines. The modern version of that workload is not one generator
but a FAMILY: which of G generators x S parallel sub-streams pass
together (Wartel & Hill 2026; Antunes et al. 2024 — PAPERS.md)? A
``Campaign`` screens that declarative grid in WAVES:

  phase 0   ``pairstream`` seam battery — the inter-stream
            disjointness/correlation check over adjacent sub-streams
            (stats/tests.pairstream at rng.generators.seam_offsets);
            a failed seam knocks out both cells that share it.
  phase 1+  the target battery at each wave scale, cheapest first
            (``scheduler.wave_schedule``); every cell the sequential
            verdict engine FAILs is knocked out of all later waves.

Each phase is ONE ``RunSpec`` whose generators tuple enumerates the
surviving cells and whose ``offsets`` tuple places each cell in its own
sub-stream — so a whole wave is one batched multi-generator dispatch
per round, on the session's cached grid executable. Offsets are runtime
arguments and the cell axis is padded to power-of-two buckets, so
knockouts never retrace: a campaign's compile count scales with the
number of phases, not the number of cells (asserted via the session's
trace counts in ``tests/test_campaign.py``).

Progress lives in the cell-keyed ``CampaignLedger`` (api.py, the
job-id-keyed checkpoint discipline) plus one per-phase run checkpoint,
so an interrupted campaign resumes mid-wave with knocked-out cells
still knocked out.

Under ``CampaignSpec(verdict_engine="evalue")`` (DESIGN.md §13) the
knockout currency changes from per-phase Bonferroni boundaries to
cumulative e-process WEALTH: each stream phase's calibrated e-values
multiply into the cell's ledger-persisted wealth, a cell FAILs the
moment cumulative wealth reaches ``1/alpha`` (valid at every look by
Ville's inequality), and a cell that finishes the last scheduled wave
merely *borderline* — wealth inside ``[continue_band/alpha, 1/alpha)``
— is RE-OPENED: a continuation phase at the top wave's scale reads
fresh (previously unread) words of each cell's sub-stream, up to
``max_continuations`` times, before the cell is force-decided. Seam
phases stay knockout-only under either engine: their reads straddle the
same words the stream phases consume, so their evidence must not be
double-counted into cell wealth.

Typical use::

    session = PoolSession()
    spec = CampaignSpec("smallcrush", generators=("splitmix64", "pcg32"),
                        n_streams=4, waves=(0.25, 1.0),
                        ledger_path="campaign.ck")
    result = Campaign(session, spec).run()
    print(result.report)
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.ckpt import io as ckpt_io
from repro.core import stitch
from repro.core.api import (CELL_FAIL, CELL_PASS, CELL_UNDECIDED,
                            CampaignLedger, CampaignSpec, PoolSession,
                            RunSpec, emit_progress)
from repro.core.battery import build_battery, max_words
from repro.core.pool import word_bucket
from repro.core.scheduler import wave_schedule
from repro.rng.generators import seam_offsets, stream_offsets


def default_span(spec: CampaignSpec) -> int:
    """The sub-stream spacing (words) that keeps every cell's reads in
    its own stream: the widest block any job of any wave's battery (or
    the seam check's half-block) consumes, rounded up to a power of two
    (``pool.word_bucket`` — same bucketing discipline as generation).
    A pure function of the spec, so ledgers and resumes agree on it."""
    words = 0
    for scale in sorted(set(spec.waves)):
        words = max(words, max_words(build_battery(spec.battery, scale)))
    if spec.stream_check and spec.n_streams > 1:
        pair = build_battery("pairstream", _stream_check_scale(spec))
        words = max(words, max_words(pair) // 2)
    return word_bucket(max(words, 1))


def _stream_check_scale(spec: CampaignSpec) -> float:
    """The seam battery runs at the cheapest wave's scale — it is a
    machinery check (overlap/correlation at stream seams is ~certain to
    trip any mode when the offset arithmetic is wrong), so the small
    screening size is enough and keeps phase 0 cheap."""
    return min(spec.waves)


@dataclasses.dataclass(frozen=True)
class Phase:
    """One screening phase: a battery at a scale, plus the per-cell
    offset rule ("stream" = cells read their own sub-stream; "seam" =
    cells straddle their right-hand seam for the pairstream check).
    ``continuation`` numbers the re-opening passes appended for
    borderline cells under the e-value engine (0 = a scheduled phase);
    continuation k advances every cell's offset past the whole grid's
    first k blocks, so each pass reads fresh words."""
    name: str
    battery: str
    scale: float
    offset_rule: str            # "stream" | "seam"
    continuation: int = 0


@dataclasses.dataclass
class CampaignResult:
    """Outcome of a campaign: the per-cell decision matrix and the
    knockout history, plus the aggregate execution counters."""
    spec: CampaignSpec
    cells: List[Tuple[str, int]]
    decisions: np.ndarray           # (C,) CELL_* codes, cell order
    decided_phase: np.ndarray       # (C,) phase index, -1 undecided
    phase_names: List[str]
    rounds_run: int
    wall_s: float
    log_wealth: Optional[np.ndarray] = None     # (C,) e-wealth (evalue)
    continuations: int = 0          # continuation phases opened

    @property
    def wealth(self) -> Optional[np.ndarray]:
        """Per-cell e-process wealth in linear space (overflow-capped),
        cell order; ``None`` under the Bonferroni engine."""
        if self.log_wealth is None:
            return None
        return np.exp(np.minimum(np.asarray(self.log_wealth, np.float64),
                                 700.0))

    @property
    def matrix(self) -> np.ndarray:
        """(generators, streams) decision matrix (CELL_* codes)."""
        return stitch.campaign_matrix(self.decisions,
                                      len(self.spec.generators),
                                      self.spec.n_streams)

    @property
    def report(self) -> str:
        """The rendered screening matrix + knockout summary."""
        return stitch.campaign_report(self.spec.generators,
                                      self.spec.n_streams, self.decisions,
                                      self.decided_phase, self.phase_names)

    def decision(self, gen: str, stream: int = 0) -> str:
        """PASS/FAIL/UNDECIDED for one (generator, stream) cell."""
        i = self.cells.index((gen, stream))
        return {CELL_UNDECIDED: stitch.UNDECIDED, CELL_PASS: stitch.PASS,
                CELL_FAIL: stitch.FAIL}[int(self.decisions[i])]

    @property
    def survivors(self) -> List[Tuple[str, int]]:
        """Cells that passed every wave."""
        return [c for c, d in zip(self.cells, self.decisions)
                if d == CELL_PASS]

    @property
    def knockouts(self) -> List[Tuple[str, int]]:
        """Cells knocked out by some phase."""
        return [c for c, d in zip(self.cells, self.decisions)
                if d == CELL_FAIL]


class Campaign:
    """Driver for one ``CampaignSpec`` on a ``PoolSession``.

    Build it, call ``run()``; with a ``ledger_path`` the campaign is
    restartable at both granularities (phase list + mid-phase rounds).
    The session outlives the campaign — screening several campaigns on
    one session shares every compiled executable the grids have in
    common."""

    def __init__(self, session: PoolSession, spec: CampaignSpec):
        self.session = session
        self.spec = spec
        need = default_span(spec)
        self.span = spec.span if spec.span is not None else need
        if spec.n_streams > 1 and self.span < need:
            raise ValueError(
                f"span={self.span} is narrower than the widest job "
                f"block ({need} words incl. bucketing); sub-streams "
                "would overlap")
        self.rounds_run = 0
        self.ledger = self._load_ledger()
        if (spec.verdict_engine == "evalue"
                and self.ledger.log_wealth is None):
            self.ledger.log_wealth = np.zeros((spec.n_cells,), np.float64)

    # -- grid bookkeeping --------------------------------------------------

    def phases(self) -> List[Phase]:
        """The campaign's phase list: the seam check (grids with >1
        stream), then the waves in ascending-scale order, then one
        continuation phase per re-opening the ledger has recorded
        (e-value engine only) — a pure function of (spec, ledger), so a
        resumed campaign reconstructs the identical list."""
        out = []
        if self.spec.stream_check and self.spec.n_streams > 1:
            out.append(Phase("streamcheck", "pairstream",
                             _stream_check_scale(self.spec), "seam"))
        for scale in wave_schedule(self.spec.waves):
            out.append(Phase(f"x{scale:g}", self.spec.battery, scale,
                             "stream"))
        top = max(self.spec.waves)
        for c in range(1, self.ledger.continuations + 1):
            out.append(Phase(f"continue{c}", self.spec.battery, top,
                             "stream", continuation=c))
        return out

    def _load_ledger(self) -> CampaignLedger:
        path = self.spec.ledger_path
        if path and ckpt_io.exists(path):
            ledger = CampaignLedger.load(path)
            if not ledger.matches(self.spec):
                raise ValueError(
                    f"campaign ledger {path} was written by a different "
                    "campaign configuration (grid, battery, waves, seed, "
                    "alpha, policy, stream_check or span) — refusing to "
                    "resume; delete the ledger to start fresh")
            return ledger
        return CampaignLedger.fresh(self.spec)

    def _save_ledger(self) -> None:
        if self.spec.ledger_path:
            self.ledger.save(self.spec.ledger_path)

    def _survivor_idx(self) -> List[int]:
        """Grid-cell positions still in play."""
        return [i for i, d in enumerate(self.ledger.decisions)
                if d == CELL_UNDECIDED]

    # -- phase execution ---------------------------------------------------

    def _phase_cells(self, phase: Phase) -> List[Tuple[int, ...]]:
        """The cells a phase dispatches, as tuples of GRID cell indices:
        a wave runs each surviving cell ``(i,)``; the seam check runs
        each adjacent PAIR ``(i, i+1)`` whose two cells both survive
        (its verdict binds both)."""
        alive = set(self._survivor_idx())
        if phase.offset_rule == "stream":
            return [(i,) for i in sorted(alive)]
        S = self.spec.n_streams
        pairs = []
        for i in sorted(alive):
            if (i % S) < S - 1 and (i + 1) in alive:
                pairs.append((i, i + 1))
        return pairs

    def _cell_offset(self, phase: Phase, cell_group: Tuple[int, ...],
                     pair_words: int) -> int:
        """The word offset the phase's RunSpec assigns this dispatch
        position (``stream_offsets``/``seam_offsets`` grids).
        Continuation phase k advances each cell by ``k * S * span``
        words — past the whole grid's first k stream blocks — so every
        re-opening reads words no scheduled phase (and no other cell's
        continuation) has touched."""
        s = int(self.ledger.streams[cell_group[0]])
        if phase.offset_rule == "stream":
            base = int(stream_offsets(s + 1, self.span)[s])
            return base + (phase.continuation * self.spec.n_streams
                           * self.span)
        return int(seam_offsets(s + 2, self.span, pair_words)[s])

    def _run_phase(self, k: int, phase: Phase) -> bool:
        """Drive one phase to its verdicts; returns True when the phase
        COMPLETED (every dispatched cell reached a decision or ran its
        full battery). False means jobs stayed HELD through the retry
        budget — the phase's partial checkpoint is kept and the caller
        must not advance past it, so a resume retries the phase instead
        of freezing its undecided cells forever."""
        groups = self._phase_cells(phase)
        if not groups:
            emit_progress(self.spec.progress,
                          f"phase {k} ({phase.name}): no surviving cells — "
                          "skipped")
            return True
        pair_words = 0
        if phase.offset_rule == "seam":
            pair_words = max_words(
                build_battery(phase.battery, phase.scale)) // 2
        srcs = [self.spec.sources[g // self.spec.n_streams]
                for g in [grp[0] for grp in groups]]
        offs = [self._cell_offset(phase, grp, pair_words) for grp in groups]
        # pad the cell axis to its power-of-two bucket (repeat cell 0;
        # padding results are discarded) so knockouts between waves
        # re-enter seen grid shapes instead of retracing — word_bucket
        # is the same rounding rule generation uses
        n_real = len(groups)
        pad = word_bucket(max(n_real, 1)) - n_real
        srcs += [srcs[0]] * pad
        offs += [offs[0]] * pad
        ck = (f"{self.spec.ledger_path}.phase{k}"
              if self.spec.ledger_path else None)
        spec = RunSpec(phase.battery, sources=tuple(srcs),
                       seeds=(self.spec.seed,), scale=phase.scale,
                       policy=self.spec.policy, retry=self.spec.retry,
                       alpha=self.spec.alpha,
                       verdict_engine=self.spec.verdict_engine,
                       backend=self.spec.backend, offsets=tuple(offs),
                       checkpoint_path=ck, progress=self.spec.progress)
        emit_progress(self.spec.progress,
                      f"phase {k} ({phase.name}): {n_real} cell(s) "
                      f"(+{pad} pad) on battery={phase.battery} "
                      f"scale={phase.scale:g}")
        # the shared drive loop (BatteryRun.drive) owns the hold/release
        # retry budget; stop_when cancels the phase's residual rounds the
        # moment every REAL cell (padding excluded) is decided. A stalled
        # phase is DATA here (the ledger keeps it retryable), so budget
        # exhaustion must not raise out of the campaign driver.
        handle = self.session.submit(spec).drive(
            stop_when=lambda h: all(
                v.decided for v in h.verdicts_by_position()[:n_real]),
            raise_on_exhausted=False)
        self.rounds_run += handle.rounds_run
        completed = handle.done or handle.cancelled
        verdicts = handle.verdicts_by_position()[:n_real]
        evalue = self.spec.verdict_engine == "evalue"
        if evalue and phase.offset_rule == "stream":
            # cumulative-wealth knockout: a stream phase's e-values fold
            # into the cell's ledger wealth ONCE, when the phase
            # completes — a stalled phase retries from its checkpoint,
            # and folding its partial wealth now would double-count on
            # the retry. Seam phases never reach here: their reads
            # overlap the stream words, so their evidence stays
            # knockout-only (the generic branch below).
            if completed:
                self._fold_wealth(k, groups, verdicts)
        else:
            for grp, v in zip(groups, verdicts):
                if v.decision == stitch.FAIL:
                    for i in grp:       # a failed seam binds both cells
                        self.ledger.decisions[i] = CELL_FAIL
                        self.ledger.decided_phase[i] = k
                elif (v.decision == stitch.PASS
                      and phase.offset_rule == "stream"
                      and k == len(self.phases()) - 1):
                    i = grp[0]          # survived the final wave
                    self.ledger.decisions[i] = CELL_PASS
                    self.ledger.decided_phase[i] = k
        return completed

    def _fold_wealth(self, k: int, groups, verdicts) -> None:
        """Fold one completed stream phase's per-cell e-process evidence
        into the ledger and decide what wealth now decides: FAIL at
        cumulative wealth >= 1/alpha (Ville boundary, valid mid-campaign);
        at the LAST currently-scheduled phase, PASS below the
        continuation band — a borderline cell (wealth in
        [band/alpha, 1/alpha)) is left UNDECIDED while continuation
        budget remains, which is what re-opens it."""
        log_thr = math.log(1.0 / self.spec.alpha)
        last = k == len(self.phases()) - 1
        band = self.spec.continue_band
        for grp, v in zip(groups, verdicts):
            i = grp[0]
            self.ledger.log_wealth[i] += v.log_wealth
            logw = float(self.ledger.log_wealth[i])
            if logw >= log_thr:
                self.ledger.decisions[i] = CELL_FAIL
                self.ledger.decided_phase[i] = k
            elif last:
                borderline = (band > 0.0
                              and logw >= log_thr + math.log(band))
                if (borderline and self.ledger.continuations
                        < self.spec.max_continuations):
                    continue            # re-opened by the next phase
                self.ledger.decisions[i] = CELL_PASS
                self.ledger.decided_phase[i] = k

    # -- public ------------------------------------------------------------

    def _wants_continuation(self) -> bool:
        """True when finishing the current phase list would still leave
        borderline (undecided) cells AND the spec's continuation budget
        has re-openings left — the condition under which the campaign
        appends a continuation phase instead of finishing."""
        if (self.spec.verdict_engine != "evalue"
                or self.spec.continue_band <= 0.0
                or self.ledger.continuations >= self.spec.max_continuations):
            return False
        return bool(np.any(self.ledger.decisions == CELL_UNDECIDED))

    @property
    def complete(self) -> bool:
        """True once the ledger records every phase as done and no
        borderline cell is waiting on a continuation re-opening."""
        if self.ledger.phases_done < len(self.phases()):
            return False
        return not self._wants_continuation()

    def run_next_phase(self) -> bool:
        """Drive ONE remaining phase — the serve daemon's unit of work
        (a campaign ticket advances a phase per daemon step instead of
        monopolizing the loop). Returns True when the phase COMPLETED
        and the ledger advanced; False when the campaign is already
        complete, or the phase stalled with jobs HELD through the retry
        budget (the saved ledger + per-phase checkpoint make the next
        call retry it instead of freezing its cells forever)."""
        phases = self.phases()
        k = self.ledger.phases_done
        if k >= len(phases):
            if not self._wants_continuation():
                return False
            # open a continuation: record it in the ledger FIRST (the
            # phase list is a pure function of (spec, ledger), so a
            # crash right after this save resumes into the same phase)
            self.ledger.continuations += 1
            self._save_ledger()
            phases = self.phases()
            emit_progress(self.spec.progress,
                          f"continuation {self.ledger.continuations}: "
                          f"{len(self._survivor_idx())} borderline "
                          f"cell(s) re-opened on fresh stream words")
        if not self._run_phase(k, phases[k]):
            self._save_ledger()     # decisions so far; phase k retries
            return False
        self.ledger.phases_done = k + 1
        self._save_ledger()
        # drop the phase's resume file only AFTER the ledger records
        # the phase as done — a crash between the two must lose the
        # checkpoint-or-progress, never both
        ck = (f"{self.spec.ledger_path}.phase{k}"
              if self.spec.ledger_path else None)
        if ck and ckpt_io.exists(ck):
            os.remove(ck)
        return True

    def result_snapshot(self, wall_s: float = 0.0) -> CampaignResult:
        """The per-cell decision matrix as it stands — valid after any
        phase boundary, not just at completion (a serve ticket's interim
        and final result both come from here)."""
        lw = (np.asarray(self.ledger.log_wealth, np.float64).copy()
              if (self.spec.verdict_engine == "evalue"
                  and self.ledger.log_wealth is not None) else None)
        return CampaignResult(
            self.spec, self.spec.cells,
            np.asarray(self.ledger.decisions, np.int8).copy(),
            np.asarray(self.ledger.decided_phase, np.int8).copy(),
            [p.name for p in self.phases()], self.rounds_run, wall_s,
            log_wealth=lw, continuations=int(self.ledger.continuations))

    def run(self) -> CampaignResult:
        """Drive every remaining phase (resuming from the ledger) and
        return the per-cell decision matrix. An incomplete phase (jobs
        HELD through the retry budget) stops the campaign at that phase
        with its cells undecided; the saved ledger + per-phase
        checkpoint make the next ``run()`` retry it."""
        t0 = time.time()
        while not self.complete:
            if not self.run_next_phase():
                break
        return self.result_snapshot(time.time() - t0)


def screen(spec: CampaignSpec,
           session: Optional[PoolSession] = None) -> CampaignResult:
    """One-call campaign: build a session (or reuse one) and run."""
    return Campaign(session or PoolSession(), spec).run()

"""superstitch — fold per-(round, worker) results into the battery report.

The paper's `superstitch` concatenated 11..107 output files into
results.txt and pulled the per-test summaries into stats.txt; here the
"files" are the (rounds, workers) result arrays plus the plan that maps
slots back to test indices. Suspicious p-values are flagged with TestU01's
convention (outside [eps, 1-eps])."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

SUSPECT_P = 1e-4


def fold(plan_assignment: np.ndarray, stats: np.ndarray, ps: np.ndarray,
         results: Dict[int, tuple] | None = None) -> Dict[int, tuple]:
    """Merge one round-set into {test_index: (stat, p)}."""
    results = dict(results or {})
    a = np.asarray(plan_assignment)
    for (r, w), idx in np.ndenumerate(a):
        if idx >= 0:
            results[int(idx)] = (float(stats[r, w]), float(ps[r, w]))
    return results


def missing(results: Dict[int, tuple], n_tests: int) -> List[int]:
    """Jobs with no / invalid results -> the HELD set (paper: condor hold)."""
    out = []
    for i in range(n_tests):
        if i not in results:
            out.append(i)
            continue
        stat, p = results[i]
        if not (np.isfinite(stat) and np.isfinite(p) and 0.0 <= p <= 1.0):
            out.append(i)
    return out


def report(entries, results: Dict[int, tuple], gen_name: str,
           seed: int) -> str:
    lines = [
        "========= CondorJAX battery results =========",
        f"generator: {gen_name}    seed: {seed}",
        f"tests: {len(entries)}",
        "-" * 46,
    ]
    n_suspect = 0
    for e in entries:
        stat, p = results.get(e.index, (float("nan"), float("nan")))
        flag = ""
        if not np.isfinite(p):
            flag = "   <-- MISSING/HELD"
        elif p < SUSPECT_P or p > 1 - SUSPECT_P:
            flag = "   <-- SUSPECT"
            n_suspect += 1
        lines.append(f"[{e.index:3d}] {e.name:32s} stat={stat:12.4f} "
                     f"p={p:10.3e}{flag}")
    lines.append("-" * 46)
    lines.append(f"suspect p-values: {n_suspect} "
                 f"({'FAIL' if n_suspect else 'pass'})")
    return "\n".join(lines)

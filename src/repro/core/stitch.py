"""superstitch — fold per-(round, worker) results into the battery report.

The paper's `superstitch` concatenated 11..107 output files into
results.txt and pulled the per-test summaries into stats.txt; here the
"files" are the (rounds, workers) result arrays plus the plan that maps
slots back to job indices. When the schedule policy over-decomposed a
test into sub-jobs, ``fold_groups`` combines each group's sub-p-values
back into one per-test verdict (Stouffer by default — keeps both tails —
or Fisher). Suspicious p-values are flagged with TestU01's convention
(outside [eps, 1-eps]).

``sequential_verdict`` is the early-stopping decision engine (DESIGN.md
§4): a Bonferroni-sequential combination over however many tests have
completed so far, valid at every interim look — which is what lets the
adaptive schedule policy cancel a definitively-failed generator after
any round without inflating the family-wise error rate."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np
from scipy import special as sps

from repro.core import evidence

SUSPECT_P = 1e-4
_P_FLOOR = 1e-15


def combine_stouffer(ps) -> tuple:
    """(stat, p): Z = sum(Phi^-1(1-p_i)) / sqrt(m), p = 1 - Phi(Z).
    Direction-preserving — p near 0 AND p near 1 both survive the fold,
    which the two-sided suspect rule needs."""
    ps = np.clip(np.asarray(ps, np.float64), _P_FLOOR, 1.0 - 1e-12)
    z = sps.ndtri(1.0 - ps)
    stat = float(z.sum() / np.sqrt(len(ps)))
    return stat, float(sps.ndtr(-stat))


def combine_fisher(ps) -> tuple:
    """(stat, p): stat = -2 sum(ln p_i) ~ chi2_{2m}; small-p sensitive."""
    ps = np.clip(np.asarray(ps, np.float64), _P_FLOOR, 1.0)
    stat = float(-2.0 * np.log(ps).sum())
    return stat, float(sps.gammaincc(len(ps), stat / 2.0))


COMBINERS = {"stouffer": combine_stouffer, "fisher": combine_fisher}


def fold(plan_assignment: np.ndarray, stats: np.ndarray, ps: np.ndarray,
         results: Dict[int, tuple] | None = None) -> Dict[int, tuple]:
    """Merge one round-set into {test_index: (stat, p)}."""
    results = dict(results or {})
    a = np.asarray(plan_assignment)
    for (r, w), idx in np.ndenumerate(a):
        if idx >= 0:
            results[int(idx)] = (float(stats[r, w]), float(ps[r, w]))
    return results


def missing(results: Dict[int, tuple], n_tests: int) -> List[int]:
    """Jobs with no / invalid results -> the HELD set (paper: condor hold)."""
    out = []
    for i in range(n_tests):
        if i not in results:
            out.append(i)
            continue
        stat, p = results[i]
        if not (np.isfinite(stat) and np.isfinite(p) and 0.0 <= p <= 1.0):
            out.append(i)
    return out


def fold_groups(job_results: Dict[int, tuple], jobs,
                combine: str = "stouffer") -> Dict[int, tuple]:
    """Map job-space results back to test-space: {entry.group: (stat, p)}.

    Unsplit jobs pass through untouched (bitwise — no combine applied), so
    non-decomposing policies see exactly the classic fold. A group with any
    missing/invalid sub-result stays missing (the whole test is HELD)."""
    groups: Dict[int, list] = {}
    for j in jobs:
        groups.setdefault(j.group, []).append(j)
    fold_fn = COMBINERS[combine]
    out: Dict[int, tuple] = {}
    for g, js in groups.items():
        if len(js) == 1 and js[0].n_parts == 1:
            if js[0].index in job_results:
                out[g] = job_results[js[0].index]
            continue
        ps = []
        ok = True
        for j in sorted(js, key=lambda j: j.part):
            sp = job_results.get(j.index)
            if sp is None or not np.isfinite(sp[1]):
                ok = False
                break
            ps.append(sp[1])
        if ok:
            out[g] = fold_fn(ps)
    return out


def demux_positions(per_position, groups: Dict) -> Dict:
    """Per-ticket demux of a coalesced dispatch (serve layer, DESIGN.md
    §10): ``per_position`` is anything indexed by generator POSITION in
    a merged multi-generator spec (``BatteryRun.results_by_position`` /
    ``verdicts_by_position``); ``groups`` maps each member (ticket id)
    to the positions its own spec contributed. Returns
    ``{member: [per_position[p] for its positions]}`` — the inverse of
    the admission batcher's spec merge."""
    out = {}
    for member, positions in groups.items():
        out[member] = [per_position[int(p)] for p in positions]
    return out


# ---------------------------------------------------------------------------
# sequential verdict engine (adaptive early stopping, DESIGN.md §4)

PASS, FAIL, UNDECIDED = "PASS", "FAIL", "UNDECIDED"


@dataclasses.dataclass(frozen=True)
class Verdict:
    """Outcome of one interim (or final) look at a generator's results.

    ``decision`` is FAIL the moment any completed test crosses the
    Bonferroni boundary, PASS only once every test has completed without
    a crossing, UNDECIDED otherwise. ``threshold`` is the per-test,
    per-tail rejection boundary actually applied."""
    decision: str                   # PASS | FAIL | UNDECIDED
    alpha: float                    # configured family-wise error rate
    threshold: float                # per-test per-tail boundary
    n_checked: int                  # tests with a valid result so far
    n_total: int                    # battery size (test space)
    failed_tests: Tuple[int, ...]   # test indices past the boundary

    @property
    def decided(self) -> bool:
        """True once the decision is PASS or FAIL (never revisited)."""
        return self.decision != UNDECIDED

    def __str__(self):
        return (f"{self.decision} (alpha={self.alpha:g}, "
                f"{self.n_checked}/{self.n_total} tests checked, "
                f"{len(self.failed_tests)} past boundary)")


def sequential_verdict(results: Dict[int, tuple], n_total: int,
                       alpha: float = 0.01) -> Verdict:
    """Interim verdict over the completed subset of an ``n_total``-test
    battery, valid after every round.

    The spending rule is Bonferroni-sequential: each of the ``n_total``
    tests is granted ``alpha / n_total`` of the family-wise budget
    (``alpha / 2n`` per tail — TestU01's suspect rule is two-sided), and
    a test's share is spent when its result lands, in whatever order the
    schedule delivers it. Because every test's boundary is fixed up
    front, the rejection decision is invariant to execution order and to
    WHEN you look — stopping at the first crossing spends exactly the
    budget of the tests examined so far, so the false-FAIL rate of the
    stopped battery is bounded by ``alpha`` regardless of how the
    adaptive policy reorders or truncates the schedule."""
    if n_total <= 0:
        raise ValueError("n_total must be positive")
    thr = alpha / (2.0 * n_total)
    failed = []
    n_checked = 0
    for i, (stat, p) in results.items():
        if not (np.isfinite(p) and 0.0 <= p <= 1.0):
            continue
        n_checked += 1
        if p < thr or p > 1.0 - thr:
            failed.append(int(i))
    if failed:
        decision = FAIL
    elif n_checked >= n_total:
        decision = PASS
    else:
        decision = UNDECIDED
    return Verdict(decision, float(alpha), float(thr), n_checked,
                   int(n_total), tuple(sorted(failed)))


# Re-exported so the verdict surface lives in one module: the e-value
# engine itself is implemented in repro.core.evidence (DESIGN.md §13).
EvidenceVerdict = evidence.EvidenceVerdict
evidence_verdict = evidence.evidence_verdict
VerdictEngineMismatch = evidence.VerdictEngineMismatch

#: The pluggable verdict engines ``RunSpec(verdict_engine=...)`` selects
#: from. Every engine shares the ``(results, n_total, alpha=...)``
#: call shape and returns a Verdict-shaped object (``decision`` /
#: ``decided`` / ``n_checked`` / ``failed_tests``).
VERDICT_ENGINES = {
    "bonferroni": sequential_verdict,
    "evalue": evidence_verdict,
}


def verdict_for(engine: str):
    """The verdict engine callable registered under ``engine``; raises
    ``KeyError`` naming the known engines for anything else."""
    try:
        return VERDICT_ENGINES[engine]
    except KeyError:
        raise KeyError(f"unknown verdict engine {engine!r}; known: "
                       f"{sorted(VERDICT_ENGINES)}") from None


# ---------------------------------------------------------------------------
# campaign matrix + summary report (DESIGN.md §8)

_CELL_GLYPH = {0: "?", 1: "P", 2: "F"}     # api.CELL_UNDECIDED/PASS/FAIL


def campaign_matrix(decisions, n_generators: int,
                    n_streams: int) -> np.ndarray:
    """The flat cell-ordered decision vector reshaped to the
    (generators, streams) verdict matrix (cell order is generator-major,
    matching ``CampaignSpec.cells``)."""
    d = np.asarray(decisions, np.int8)
    if d.size != n_generators * n_streams:
        raise ValueError(f"{d.size} cell decisions for a "
                         f"{n_generators} x {n_streams} grid")
    return d.reshape(n_generators, n_streams)


def campaign_report(generators, n_streams: int, decisions,
                    decided_phase, phase_names) -> str:
    """The campaign's superstitch: the per-cell PASS/FAIL/UNDECIDED
    matrix (rows = generators, columns = sub-streams; each decided cell
    shows its verdict glyph and the phase that decided it) plus the
    knockout summary per phase."""
    generators = list(generators)
    mat = campaign_matrix(decisions, len(generators), n_streams)
    phase = np.asarray(decided_phase, np.int8).reshape(len(generators),
                                                      n_streams)
    lines = [
        "========= campaign screening matrix =========",
        f"grid: {len(generators)} generator(s) x {n_streams} stream(s)   "
        f"phases: {', '.join(phase_names)}",
        "-" * 46,
        "generator      | " + " ".join(f"s{s:<3d}" for s in range(n_streams)),
    ]
    for g, gen in enumerate(generators):
        cells = []
        for s in range(n_streams):
            glyph = _CELL_GLYPH[int(mat[g, s])]
            tag = f"{glyph}@{int(phase[g, s])}" if mat[g, s] else f"{glyph}  "
            cells.append(f"{tag:4s}")
        lines.append(f"{gen:14s} | " + " ".join(cells))
    lines.append("-" * 46)
    n_pass = int(np.sum(mat == 1))
    n_fail = int(np.sum(mat == 2))
    n_open = int(np.sum(mat == 0))
    lines.append(f"cells: {mat.size}  pass: {n_pass}  fail: {n_fail}  "
                 f"undecided: {n_open}")
    for p, name in enumerate(phase_names):
        knocked = int(np.sum((phase == p) & (mat == 2)))
        if knocked:
            lines.append(f"  phase {p} ({name}): knocked out {knocked} "
                         f"cell(s)")
    return "\n".join(lines)


def report(entries, results: Dict[int, tuple], gen_name: str,
           seed: int) -> str:
    """The classic battery text report: one line per test with its
    (stat, p), MISSING/HELD and SUSPECT flags (TestU01's two-sided
    convention), and the suspect-count verdict footer."""
    lines = [
        "========= CondorJAX battery results =========",
        f"generator: {gen_name}    seed: {seed}",
        f"tests: {len(entries)}",
        "-" * 46,
    ]
    n_suspect = 0
    for e in entries:
        stat, p = results.get(e.index, (float("nan"), float("nan")))
        flag = ""
        if not np.isfinite(p):
            flag = "   <-- MISSING/HELD"
        elif p < SUSPECT_P or p > 1 - SUSPECT_P:
            flag = "   <-- SUSPECT"
            n_suspect += 1
        lines.append(f"[{e.index:3d}] {e.name:32s} stat={stat:12.4f} "
                     f"p={p:10.3e}{flag}")
    lines.append("-" * 46)
    lines.append(f"suspect p-values: {n_suspect} "
                 f"({'FAIL' if n_suspect else 'pass'})")
    return "\n".join(lines)

"""superstitch — fold per-(round, worker) results into the battery report.

The paper's `superstitch` concatenated 11..107 output files into
results.txt and pulled the per-test summaries into stats.txt; here the
"files" are the (rounds, workers) result arrays plus the plan that maps
slots back to job indices. When the schedule policy over-decomposed a
test into sub-jobs, ``fold_groups`` combines each group's sub-p-values
back into one per-test verdict (Stouffer by default — keeps both tails —
or Fisher). Suspicious p-values are flagged with TestU01's convention
(outside [eps, 1-eps])."""
from __future__ import annotations

from typing import Dict, List

import numpy as np
from scipy import special as sps

SUSPECT_P = 1e-4
_P_FLOOR = 1e-15


def combine_stouffer(ps) -> tuple:
    """(stat, p): Z = sum(Phi^-1(1-p_i)) / sqrt(m), p = 1 - Phi(Z).
    Direction-preserving — p near 0 AND p near 1 both survive the fold,
    which the two-sided suspect rule needs."""
    ps = np.clip(np.asarray(ps, np.float64), _P_FLOOR, 1.0 - 1e-12)
    z = sps.ndtri(1.0 - ps)
    stat = float(z.sum() / np.sqrt(len(ps)))
    return stat, float(sps.ndtr(-stat))


def combine_fisher(ps) -> tuple:
    """(stat, p): stat = -2 sum(ln p_i) ~ chi2_{2m}; small-p sensitive."""
    ps = np.clip(np.asarray(ps, np.float64), _P_FLOOR, 1.0)
    stat = float(-2.0 * np.log(ps).sum())
    return stat, float(sps.gammaincc(len(ps), stat / 2.0))


COMBINERS = {"stouffer": combine_stouffer, "fisher": combine_fisher}


def fold(plan_assignment: np.ndarray, stats: np.ndarray, ps: np.ndarray,
         results: Dict[int, tuple] | None = None) -> Dict[int, tuple]:
    """Merge one round-set into {test_index: (stat, p)}."""
    results = dict(results or {})
    a = np.asarray(plan_assignment)
    for (r, w), idx in np.ndenumerate(a):
        if idx >= 0:
            results[int(idx)] = (float(stats[r, w]), float(ps[r, w]))
    return results


def missing(results: Dict[int, tuple], n_tests: int) -> List[int]:
    """Jobs with no / invalid results -> the HELD set (paper: condor hold)."""
    out = []
    for i in range(n_tests):
        if i not in results:
            out.append(i)
            continue
        stat, p = results[i]
        if not (np.isfinite(stat) and np.isfinite(p) and 0.0 <= p <= 1.0):
            out.append(i)
    return out


def fold_groups(job_results: Dict[int, tuple], jobs,
                combine: str = "stouffer") -> Dict[int, tuple]:
    """Map job-space results back to test-space: {entry.group: (stat, p)}.

    Unsplit jobs pass through untouched (bitwise — no combine applied), so
    non-decomposing policies see exactly the classic fold. A group with any
    missing/invalid sub-result stays missing (the whole test is HELD)."""
    groups: Dict[int, list] = {}
    for j in jobs:
        groups.setdefault(j.group, []).append(j)
    fold_fn = COMBINERS[combine]
    out: Dict[int, tuple] = {}
    for g, js in groups.items():
        if len(js) == 1 and js[0].n_parts == 1:
            if js[0].index in job_results:
                out[g] = job_results[js[0].index]
            continue
        ps = []
        ok = True
        for j in sorted(js, key=lambda j: j.part):
            sp = job_results.get(j.index)
            if sp is None or not np.isfinite(sp[1]):
                ok = False
                break
            ps.append(sp[1])
        if ok:
            out[g] = fold_fn(ps)
    return out


def report(entries, results: Dict[int, tuple], gen_name: str,
           seed: int) -> str:
    lines = [
        "========= CondorJAX battery results =========",
        f"generator: {gen_name}    seed: {seed}",
        f"tests: {len(entries)}",
        "-" * 46,
    ]
    n_suspect = 0
    for e in entries:
        stat, p = results.get(e.index, (float("nan"), float("nan")))
        flag = ""
        if not np.isfinite(p):
            flag = "   <-- MISSING/HELD"
        elif p < SUSPECT_P or p > 1 - SUSPECT_P:
            flag = "   <-- SUSPECT"
            n_suspect += 1
        lines.append(f"[{e.index:3d}] {e.name:32s} stat={stat:12.4f} "
                     f"p={p:10.3e}{flag}")
    lines.append("-" * 46)
    lines.append(f"suspect p-values: {n_suspect} "
                 f"({'FAIL' if n_suspect else 'pass'})")
    return "\n".join(lines)

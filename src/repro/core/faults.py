"""Deterministic fault injection for the battery pool (DESIGN.md §12).

The paper's pools are *opportunistic*: idle workstations join the pool
and are reclaimed without warning, so jobs get evicted, held, and
straggled as a matter of course (condor_vacate / condor_release).  The
reproduction survives all of that through the hold/release discipline,
but until this module it could neither *provoke* those failures nor
prove the recovery bitwise.  `FaultPlan` is a declarative, seeded
schedule of faults; `FaultInjector` replays it bit-for-bit from
``(plan, seed)`` at the host-side runner boundary in ``pool.py`` —
after the traced executable returns, before results are folded — so
compiled kernels and trace caches never see a fault.

Fault kinds (``FAULT_KINDS``):

  evict        result for the slot is nulled to NaN → stitch marks the
               job HELD and the retry machinery replans it (the
               condor_vacate path).
  corrupt      the slot's (stat, p) float64 bits are perturbed — a
               *silent* corruption that the result sanity gate in
               ``api.BatteryRun`` must catch (p outside [0,1] /
               non-finite) and convert to HELD instead of a verdict.
  straggle     the slot's simulated latency is inflated by ``delay_s``;
               when ``RetryPolicy.deadline`` is set and exceeded the
               job is converted to HELD, otherwise the event is only
               recorded in the ledger.
  lose_worker  the pool width drops via the existing elastic ``resize``
               path at the next round boundary (machine reclaimed).

Everything here is host-side numpy + stdlib; nothing imports jax, so
fault logic can never leak into a traced context (rule RPA106 enforces
the same property at call sites).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

FAULT_KINDS = ("evict", "corrupt", "straggle", "lose_worker")


class CorruptResultError(ValueError):
    """A runner returned a result that fails the sanity gate.

    Raised-or-recorded when a non-idle slot reports a non-finite stat,
    a non-finite p, or a p outside [0, 1].  The drive loop never lets
    this become a verdict: the offending job is nulled to NaN, folded
    as missing, and replanned on the next release pass.
    """


def _bit_flip(x: float) -> float:
    """Flip bit 62 (the top exponent bit) of a float64.

    Chosen so corruption is *detectable by construction*: any p-value
    in [0, 1] maps to a huge (>1) or non-finite float, which the
    sanity gate rejects.  Deterministic, involutive, no randomness.
    """
    u = np.array([x], dtype=np.float64).view(np.uint64)
    u ^= np.uint64(1) << np.uint64(62)
    return float(u.view(np.float64)[0])


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One line of a fault schedule.

    ``kind`` is one of ``FAULT_KINDS``.  ``round``/``slot``/``job``
    select where the fault fires (``None`` = any); ``p`` is the
    per-match Bernoulli probability drawn deterministically from the
    plan seed; ``delay_s`` is the injected latency for ``straggle``;
    ``width`` is the post-fault pool width for ``lose_worker``
    (default: current width − 1, floored at 1).
    """

    kind: str
    round: Optional[int] = None
    slot: Optional[int] = None
    job: Optional[int] = None
    p: float = 1.0
    delay_s: float = 0.0
    width: Optional[int] = None

    def __post_init__(self):
        """Reject malformed rules up front (typed, not at fire time)."""
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if not (0.0 < self.p <= 1.0):
            raise ValueError(f"fault probability must be in (0, 1], "
                             f"got {self.p}")
        if self.round is not None and self.round < 0:
            raise ValueError(f"round must be >= 0, got {self.round}")
        if self.slot is not None and self.slot < 0:
            raise ValueError(f"slot must be >= 0, got {self.slot}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")
        if self.width is not None and self.width < 1:
            raise ValueError(f"width must be >= 1, got {self.width}")

    def to_dict(self) -> Dict:
        """JSON-safe dict (``None`` fields elided) for the wire format."""
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items()
                if v is not None and not (k == "p" and v == 1.0)
                and not (k == "delay_s" and v == 0.0)}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A declarative, seeded fault schedule (the ``--inject`` payload).

    Frozen and hashable so it can ride on a ``RunSpec``; the ``seed``
    plus a rule's index fully determine every probabilistic draw, so a
    plan replays bit-for-bit across runs, checkpoint resumes, and
    machines.
    """

    rules: Tuple[FaultRule, ...] = ()
    seed: int = 0

    def __post_init__(self):
        """Normalise ``rules`` to a tuple of FaultRule."""
        object.__setattr__(self, "rules", tuple(self.rules))
        for r in self.rules:
            if not isinstance(r, FaultRule):
                raise TypeError(f"rules must be FaultRule, got {type(r)}")

    def to_dict(self) -> Dict:
        """JSON-safe dict: ``{"seed": ..., "rules": [...]}``."""
        return {"seed": self.seed,
                "rules": [r.to_dict() for r in self.rules]}

    @classmethod
    def from_dict(cls, d: Dict) -> "FaultPlan":
        """Inverse of :meth:`to_dict` (unknown keys are an error)."""
        rules = tuple(FaultRule(**r) for r in d.get("rules", ()))
        return cls(rules=rules, seed=int(d.get("seed", 0)))

    def save(self, path: str) -> None:
        """Write the plan as JSON (the ``--inject PLAN.json`` format)."""
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        """Read a plan written by :meth:`save` (or by hand)."""
        with open(path, encoding="utf-8") as f:
            return cls.from_dict(json.load(f))


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One ledger entry: what fired, where, and why.

    ``rule`` is the index into ``plan.rules`` (−1 for events the
    injector did not cause, e.g. sanity-gate detections and
    quarantines).  The ledger is plain data so ``--json`` can carry it
    verbatim.
    """

    round: int
    kind: str
    slot: int
    job: int
    rule: int = -1
    detail: str = ""

    def to_dict(self) -> Dict:
        """JSON-safe dict for the ``--json`` fault ledger."""
        return dataclasses.asdict(self)


class WorkerHealth:
    """Per-slot consecutive-fault counters (the quarantine input).

    A slot's counter bumps on every round it faulted and resets on
    every clean round; slots whose counter reaches
    ``RetryPolicy.quarantine_after`` are reported flaky.  After a
    re-mesh (resize) slot identities change, so the caller resets all
    counters via :meth:`reset`.
    """

    def __init__(self):
        """Start with no history and no quarantined slots."""
        self._consecutive: Dict[int, int] = {}
        self.total_faults = 0

    def record(self, slot: int, faulted: bool) -> None:
        """Bump ``slot``'s streak if it faulted this round, else reset it."""
        if faulted:
            self._consecutive[slot] = self._consecutive.get(slot, 0) + 1
            self.total_faults += 1
        else:
            self._consecutive[slot] = 0

    def consecutive(self, slot: int) -> int:
        """Current consecutive-fault streak for ``slot``."""
        return self._consecutive.get(slot, 0)

    def flaky(self, threshold: int) -> List[int]:
        """Slots whose streak has reached ``threshold`` (sorted)."""
        return sorted(s for s, c in self._consecutive.items()
                      if c >= threshold)

    def reset(self) -> None:
        """Forget all streaks (called after a re-mesh renumbers slots)."""
        self._consecutive.clear()


class FaultInjector:
    """Replays a :class:`FaultPlan` against dispatch rounds.

    Stateless apart from the event ledger: every probabilistic draw is
    ``sha256(seed, rule_index, round, slot)``, so the same plan against
    the same schedule produces the same faults — including across a
    checkpoint resume, where earlier rounds are simply never
    re-dispatched.
    """

    def __init__(self, plan: FaultPlan):
        """Bind the injector to one plan; the ledger starts empty."""
        if not isinstance(plan, FaultPlan):
            raise TypeError(f"expected FaultPlan, got {type(plan)}")
        self.plan = plan
        self.events: List[FaultEvent] = []

    def _draw(self, rule_idx: int, round_idx: int, slot: int) -> bool:
        """Deterministic Bernoulli(p) draw for one (rule, round, slot)."""
        rule = self.plan.rules[rule_idx]
        if rule.p >= 1.0:
            return True
        key = f"{self.plan.seed}:{rule_idx}:{round_idx}:{slot}".encode()
        h = hashlib.sha256(key).digest()
        return int.from_bytes(h[:8], "big") / 2.0 ** 64 < rule.p

    def matches(self, round_idx: int,
                row: np.ndarray) -> List[Tuple[int, FaultRule, int]]:
        """Resolve which (rule, slot) pairs fire for this round's row.

        ``row`` is the round's job assignment (job id per slot, −1 =
        idle).  Idle slots never fault — there is nothing to evict.
        ``lose_worker`` rules are slot-independent and fire at most
        once per round (reported with slot −1).
        """
        out: List[Tuple[int, FaultRule, int]] = []
        for idx, rule in enumerate(self.plan.rules):
            if rule.round is not None and rule.round != round_idx:
                continue
            if rule.kind == "lose_worker":
                if self._draw(idx, round_idx, -1):
                    out.append((idx, rule, -1))
                continue
            if rule.slot is not None:
                slots = [rule.slot] if rule.slot < row.shape[0] else []
            else:
                slots = list(range(row.shape[0]))
            for s in slots:
                if int(row[s]) < 0:
                    continue                      # idle sentinel: no job
                if rule.job is not None and int(row[s]) != rule.job:
                    continue
                if self._draw(idx, round_idx, s):
                    out.append((idx, rule, s))
        return out

    def apply_round(self, round_idx: int, row: np.ndarray,
                    arrays: Sequence[Tuple[np.ndarray, np.ndarray]],
                    deadline: Optional[float] = None,
                    ) -> Tuple[List[FaultEvent], Optional[int]]:
        """Mutate one round's host-side results according to the plan.

        ``arrays`` is a sequence of per-generator ``(stats, ps)`` pairs
        shaped (W,), exactly as the runner returned them; mutation
        happens in place.  Returns ``(events, resize_to)`` where
        ``resize_to`` is the requested post-round width (``None`` if no
        ``lose_worker`` fired).  Events are also appended to
        ``self.events``.
        """
        events: List[FaultEvent] = []
        resize_to: Optional[int] = None
        delays: Dict[int, float] = {}
        for idx, rule, slot in self.matches(round_idx, row):
            if rule.kind == "lose_worker":
                want = rule.width if rule.width is not None \
                    else row.shape[0] - 1
                resize_to = max(1, int(want))
                events.append(FaultEvent(
                    round_idx, "lose_worker", -1, -1, idx,
                    f"pool width drops to {resize_to} after this round"))
                continue
            job = int(row[slot])
            if rule.kind == "evict":
                for st, pv in arrays:
                    st[slot] = np.nan
                    pv[slot] = np.nan
                events.append(FaultEvent(
                    round_idx, "evict", slot, job, idx,
                    "result nulled; job goes HELD (condor_vacate)"))
            elif rule.kind == "corrupt":
                for st, pv in arrays:
                    st[slot] = _bit_flip(float(st[slot]))
                    pv[slot] = _bit_flip(float(pv[slot]))
                events.append(FaultEvent(
                    round_idx, "corrupt", slot, job, idx,
                    "stat/p bits perturbed (silent corruption)"))
            elif rule.kind == "straggle":
                delays[slot] = delays.get(slot, 0.0) + rule.delay_s
                held = deadline is not None and delays[slot] > deadline
                if held:
                    for st, pv in arrays:
                        st[slot] = np.nan
                        pv[slot] = np.nan
                events.append(FaultEvent(
                    round_idx, "straggle", slot, job, idx,
                    f"latency +{rule.delay_s:g}s"
                    + (f" > deadline {deadline:g}s; job HELD" if held
                       else " (within deadline)" if deadline is not None
                       else " (no deadline set)")))
        self.events.extend(events)
        return events, resize_to

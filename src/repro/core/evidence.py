"""Anytime-valid e-value verdicts: calibrators, wealth, and the
``evalue`` verdict engine (DESIGN.md §13).

The Bonferroni-sequential engine in :mod:`repro.core.stitch` splits its
error budget up front and can only answer PASS/FAIL/UNDECIDED against a
fixed p-value boundary.  This module implements the second engine the
battery/campaign stack can select via ``RunSpec(verdict_engine=...)``:
each test's p-value is *calibrated* into an e-value (a nonnegative
statistic with expectation at most 1 under the null), e-values multiply
into a battery-level wealth process, and by Ville's inequality

    P( sup_t  W_t >= 1/alpha )  <=  alpha

rejecting whenever wealth crosses ``1/alpha`` is valid at every data-
independent stopping time — and stays valid if a borderline campaign
cell is *re-opened* later (optional continuation), which the Bonferroni
engine cannot offer.

Two calibrator families are provided:

* the power family ``e_kappa(p) = kappa * p**(kappa - 1)`` for
  ``kappa`` in (0, 1), and
* the mixture calibrator ``F(p) = (1 - p + p*ln p) / (p * (ln p)**2)``,
  the closed form of ``integral_0^1 e_kappa(p) dkappa``, which needs no
  tuning parameter and dominates every single ``kappa`` up to a
  logarithmic factor.

Battery p-values follow TestU01's two-sided suspect rule, so raw
p-values are folded through :func:`two_sided_p` before calibration —
``min(1, 2*min(p, 1-p))`` is exactly uniform when ``p`` is, keeping the
unit-mean guarantee.  All wealth arithmetic is done in log space so a
catastrophic p-value (randu at Crush scale can reach 1e-300) cannot
overflow float64.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np

# Decision labels — kept textually identical to stitch's so the two
# engines are drop-in interchangeable (test_evidence pins the equality;
# importing stitch here would be circular, stitch re-exports us).
PASS, FAIL, UNDECIDED = "PASS", "FAIL", "UNDECIDED"

#: Calibrator names accepted by :func:`log_evalue` / :func:`evidence_verdict`.
CALIBRATORS = ("kappa", "mixture")

# p-values are clamped here before taking logs: below this float64 has
# no headroom anyway and the e-value is astronomically past any boundary.
_P_FLOOR = 1e-300
# Above 1 - _P_CEIL_GAP the mixture's 0/0 form cancels catastrophically
# in floats; the calibrator is continuous there so we return its p -> 1
# limit of 1/2 instead.
_P_CEIL_GAP = 1e-6
# exp() overflow guard for wealth reported in linear space.
_LOG_WEALTH_CAP = 700.0


class VerdictEngineMismatch(ValueError):
    """Raised when persisted run state (checkpoint or campaign ledger)
    recorded under one verdict engine is resumed by a spec that selects
    a different engine — the two engines' decisions are not comparable,
    so the resume is refused rather than silently re-judged."""


def two_sided_p(p: float) -> float:
    """Fold a raw battery p-value through TestU01's two-sided suspect
    rule: ``min(1, 2 * min(p, 1 - p))``.

    If ``p`` is uniform on (0, 1) the folded value is uniform too, so
    calibrating the folded p-value preserves the unit-mean e-value
    guarantee while flagging both tails, exactly like the Bonferroni
    engine's symmetric boundary.
    """
    p = float(p)
    if not (0.0 <= p <= 1.0) or not math.isfinite(p):
        raise ValueError(f"p-value out of [0, 1]: {p!r}")
    return min(1.0, 2.0 * min(p, 1.0 - p))


def kappa_calibrator(p: float, kappa: float = 0.5) -> float:
    """The power-family calibrator ``e_kappa(p) = kappa * p**(kappa-1)``
    for ``kappa`` in (0, 1); ``integral_0^1 e_kappa(p) dp = 1`` exactly,
    so ``e_kappa(U)`` has unit mean under the null."""
    return math.exp(log_kappa_evalue(p, kappa))


def log_kappa_evalue(p: float, kappa: float = 0.5) -> float:
    """``log e_kappa(p)`` computed directly in log space —
    ``log(kappa) + (kappa - 1) * log(p)`` — so tiny p-values never
    overflow the linear form."""
    if not (0.0 < kappa < 1.0):
        raise ValueError(f"kappa must lie in (0, 1), got {kappa!r}")
    p = _clamp_p(p)
    return math.log(kappa) + (kappa - 1.0) * math.log(p)


def mixture_calibrator(p: float) -> float:
    """The mixture calibrator ``F(p) = (1 - p + p*ln p)/(p * (ln p)**2)``,
    i.e. ``integral_0^1 kappa * p**(kappa-1) dkappa`` in closed form;
    parameter-free, unit mean, with ``F(p) -> 1/2`` as ``p -> 1``."""
    return math.exp(log_mixture_evalue(p))


def log_mixture_evalue(p: float) -> float:
    """``log F(p)`` for the mixture calibrator, stable down to the
    p-value floor: for small ``p`` the numerator tends to 1 and the
    log splits into ``-log p - 2 log(-log p)``; near ``p = 1`` the 0/0
    form is replaced by its limit ``log(1/2)``."""
    p = _clamp_p(p)
    if p >= 1.0 - _P_CEIL_GAP:
        return math.log(0.5)
    lp = math.log(p)
    return math.log(1.0 - p + p * lp) - lp - 2.0 * math.log(-lp)


def log_evalue(p: float, calibrator: str = "mixture",
               kappa: float = 0.5) -> float:
    """Calibrate one (already uniform-under-null) p-value into a log
    e-value under the named calibrator.  Callers feeding raw two-sided
    battery p-values should fold them through :func:`two_sided_p`
    first — :func:`evidence_verdict` does so."""
    if calibrator == "mixture":
        return log_mixture_evalue(p)
    if calibrator == "kappa":
        return log_kappa_evalue(p, kappa)
    raise KeyError(
        f"unknown calibrator {calibrator!r}; known: {list(CALIBRATORS)}")


def combine_log_wealth(parts) -> float:
    """Merge independent log-wealth contributions into one e-process by
    summation (e-values compose by product).  Plain float addition, so
    the merge commutes and associates — the property tests pin this."""
    return float(sum(float(x) for x in parts))


def wealth_from_log(log_wealth: float) -> float:
    """Linear-space wealth ``exp(log_wealth)``, capped so a catastrophic
    test cannot overflow float64 in reports; decisions always compare in
    log space and never go through this cap."""
    return math.exp(min(float(log_wealth), _LOG_WEALTH_CAP))


def battery_log_evalues(results: Dict[int, Tuple[float, float]],
                        calibrator: str = "mixture",
                        kappa: float = 0.5) -> Dict[int, float]:
    """Per-test log e-values for a battery result dict mapping test
    index to ``(statistic, p_value)``.  Non-finite or out-of-range
    p-values are skipped (same gate as the Bonferroni engine) so a
    corrupted worker result cannot poison the wealth product."""
    out: Dict[int, float] = {}
    for idx, (_stat, p) in results.items():
        p = float(p)
        if not np.isfinite(p) or p < 0.0 or p > 1.0:
            continue
        out[int(idx)] = log_evalue(two_sided_p(p), calibrator, kappa)
    return out


def _clamp_p(p: float) -> float:
    p = float(p)
    if not (0.0 <= p <= 1.0) or not math.isfinite(p):
        raise ValueError(f"p-value out of [0, 1]: {p!r}")
    return min(max(p, _P_FLOOR), 1.0)


@dataclasses.dataclass(frozen=True)
class EvidenceVerdict:
    """Anytime-valid battery verdict — duck-compatible with
    :class:`repro.core.stitch.Verdict` (same ``decision`` / ``alpha`` /
    ``n_checked`` / ``n_total`` / ``failed_tests`` / ``decided``
    surface) plus the evidence trail: accumulated ``log_wealth``, the
    Ville boundary ``threshold = 1/alpha`` it is judged against, the
    continuation ``band``, and the per-test log e-values that compose
    the wealth trajectory."""

    decision: str
    alpha: float
    threshold: float            # Ville wealth boundary, 1/alpha
    n_checked: int
    n_total: int
    failed_tests: Tuple[int, ...]
    log_wealth: float = 0.0
    band: float = 0.0
    log_evalues: Tuple[Tuple[int, float], ...] = ()

    @property
    def decided(self) -> bool:
        """True once the verdict is PASS or FAIL."""
        return self.decision != UNDECIDED

    @property
    def wealth(self) -> float:
        """Accumulated wealth in linear space (overflow-capped); the
        run FAILs when this reaches ``threshold = 1/alpha``."""
        return wealth_from_log(self.log_wealth)

    @property
    def borderline(self) -> bool:
        """True when the battery completed UNDECIDED inside the
        continuation band ``[band/alpha, 1/alpha)`` — the campaign layer
        re-opens such cells in the next wave instead of force-deciding
        them."""
        if self.band <= 0.0 or self.decision != UNDECIDED:
            return False
        return (self.n_checked >= self.n_total
                and self.log_wealth >= _log_band_floor(self.alpha, self.band))

    @property
    def trajectory(self) -> Tuple[float, ...]:
        """Cumulative wealth after each checked test, in ascending test
        index order — the canonical (order-invariant) trajectory that
        the CLI serialises under ``--json``."""
        out: List[float] = []
        acc = 0.0
        for _idx, le in self.log_evalues:
            acc += le
            out.append(wealth_from_log(acc))
        return tuple(out)

    def __str__(self) -> str:
        """Render like stitch's Verdict but with the wealth level, e.g.
        ``FAIL (alpha=0.01, wealth=3.2e+05 vs 100, 12/96 tests
        checked)``."""
        return (f"{self.decision} (alpha={self.alpha:g}, "
                f"wealth={self.wealth:.3g} vs {self.threshold:g}, "
                f"{self.n_checked}/{self.n_total} tests checked)")


def _log_band_floor(alpha: float, band: float) -> float:
    """Log-wealth at the bottom of the continuation band,
    ``log(band / alpha)``."""
    return math.log(band) + math.log(1.0 / alpha)


def evidence_verdict(results: Dict[int, Tuple[float, float]],
                     n_total: int, alpha: float = 0.01,
                     calibrator: str = "mixture", kappa: float = 0.5,
                     band: float = 0.0) -> EvidenceVerdict:
    """The ``evalue`` verdict engine: calibrate each completed test's
    p-value into an e-value, multiply into wealth, and judge it against
    Ville's boundary ``1/alpha``.

    FAIL as soon as wealth reaches ``1/alpha`` (anytime-valid, so the
    battery may stop immediately); PASS only when all ``n_total`` tests
    completed below the boundary — unless ``band > 0`` and the final
    wealth sits inside ``[band/alpha, 1/alpha)``, in which case the
    verdict stays UNDECIDED (borderline) so the campaign layer can
    re-open the cell with fresh stream words.  ``failed_tests`` lists
    tests whose *single* e-value clears the boundary on its own.

    The verdict is a pure function of the completed result *set* —
    independent of arrival order — which is what makes checkpoint resume
    recompute the identical decision.
    """
    if n_total <= 0:
        raise ValueError(f"n_total must be positive, got {n_total}")
    if not (0.0 < alpha < 1.0):
        raise ValueError(f"alpha must lie in (0, 1), got {alpha!r}")
    if not (0.0 <= band < 1.0):
        raise ValueError(f"band must lie in [0, 1), got {band!r}")
    per_test = battery_log_evalues(results, calibrator, kappa)
    log_thr = math.log(1.0 / alpha)
    log_wealth = combine_log_wealth(per_test.values())
    failed = tuple(sorted(i for i, le in per_test.items() if le >= log_thr))
    n_checked = len(per_test)
    if log_wealth >= log_thr:
        decision = FAIL
    elif n_checked >= int(n_total):
        if band > 0.0 and log_wealth >= _log_band_floor(alpha, band):
            decision = UNDECIDED        # borderline: continuation material
        else:
            decision = PASS
    else:
        decision = UNDECIDED
    return EvidenceVerdict(
        decision, float(alpha), 1.0 / float(alpha), n_checked,
        int(n_total), failed, log_wealth, float(band),
        tuple(sorted(per_test.items())))

"""SPMD battery pool — the HTCondor pool mapped onto a device mesh.

One compiled program covers the whole battery: a worker's round executes
``lax.switch`` over the uniform job table (every test kernel has signature
``bits -> (stat, p)``), with the job's bit-stream derived from
``(seed, test_id)`` — fresh-generator-per-test semantics (paper §4.1).

``run_round`` dispatches ONE round across workers via ``shard_map`` (the
paper's "submit a batch, wait for output files"); the host driver in
``core/queue.py`` loops rounds so progress is checkpointable between
batches, exactly like the paper's `master` polling `empty`.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.battery import TestEntry, max_words
from repro.rng.generators import gen_block_by_id, x64


def _job_fn(entries: List[TestEntry], n_words: int):
    """(job_id, seed, gen_id) -> (stat, p). job_id == -1 -> idle."""
    branches = [lambda bits, e=e: tuple(
        jnp.asarray(v, jnp.float32) for v in e.kernel(bits))
        for e in entries]
    branches.append(lambda bits: (jnp.float32(0.0), jnp.float32(jnp.nan)))

    def run(job_id, seed, gen_id):
        with x64():
            bits = gen_block_by_id(gen_id, seed, jnp.maximum(job_id, 0),
                                   n_words)
        idx = jnp.where(job_id < 0, len(entries), job_id)
        return jax.lax.switch(jnp.clip(idx, 0, len(entries)), branches, bits)

    return run


def make_round_runner(entries: List[TestEntry], mesh):
    """Compiled fn: (round_assignment (W,), seed, gen_id) -> stats, ps (W,)."""
    n_words = max_words(entries)
    job = _job_fn(entries, n_words)

    @functools.partial(
        jax.shard_map, mesh=mesh, in_specs=(P("workers"), P(), P()),
        out_specs=(P("workers"), P("workers")), check_vma=False)
    def round_fn(jobs, seed, gen_id):
        stat, p = job(jobs[0], seed, gen_id)
        return stat[None], p[None]

    return jax.jit(round_fn)


def make_batch_runner(entries: List[TestEntry], mesh):
    """Whole-plan runner: (plan (R, W), seed, gen_id) -> (R, W) stats/ps.
    Single dispatch — used by benchmarks; the checkpointing driver prefers
    round-by-round."""
    n_words = max_words(entries)
    job = _job_fn(entries, n_words)

    @functools.partial(
        jax.shard_map, mesh=mesh, in_specs=(P(None, "workers"), P(), P()),
        out_specs=(P(None, "workers"), P(None, "workers")), check_vma=False)
    def plan_fn(jobs, seed, gen_id):
        def body(_, jid):
            s, p = job(jid[0], seed, gen_id)
            return 0, (s, p)
        _, (stats, ps) = jax.lax.scan(body, 0, jobs)
        return stats[:, None], ps[:, None]

    return jax.jit(plan_fn)


def run_sequential(entries: List[TestEntry], seed: int, gen_id: int):
    """Stock-TestU01 model: every test in order on ONE worker (baseline)."""
    n_words = max_words(entries)
    job = _job_fn(entries, n_words)

    @jax.jit
    def go(seed, gen_id):
        def body(_, jid):
            s, p = job(jid, seed, gen_id)
            return 0, (s, p)
        _, (stats, ps) = jax.lax.scan(
            body, 0, jnp.arange(len(entries), dtype=jnp.int32))
        return stats, ps

    return go(jnp.asarray(seed), jnp.asarray(gen_id))

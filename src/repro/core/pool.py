"""SPMD battery pool — the HTCondor pool mapped onto a device mesh.

One compiled program covers the whole battery: a worker's round executes
``lax.switch`` over the uniform job table (every test kernel has signature
``bits -> (stat, p)``), with the job's bit-stream derived from
``(seed, stream_table[job_id])`` — fresh-generator-per-test semantics
(paper §4.1). For a plain battery the stream table is the identity, so
results are bitwise those of the classic path; over-decomposed sub-jobs
get disjoint sub-streams (``group + n_groups * part``) that are stable
across pool width and schedule, which keeps hold/release and speculative
re-execution reconcilable.

Three compiled shapes, all pure functions of the job table (generator and
seed are runtime arguments — the same executable serves every generator,
which is what ``PoolSession``'s compile cache exploits):

  ``make_round_runner``   one round across workers via ``shard_map`` (the
                          paper's "submit a batch, wait for output files");
                          the host driver in ``core/api.py`` loops rounds so
                          progress is checkpointable between batches.
  ``make_fanout_runner``  the same round vmapped over a ``gen_ids`` axis —
                          G generators assessed in ONE dispatch (multi-
                          generator batteries, Wartel & Hill-style).
  ``make_grid_runner``    the fan-out with a per-lane runtime stream
                          offset — the campaign screening grid's
                          (generator, sub-stream) cells in one dispatch
                          (core/campaign.py, DESIGN.md §8).
  ``make_batch_runner``   whole plan in one dispatch (benchmarks).

``on_trace`` (when given) fires once per trace of the round body; the
session uses it to assert/count cache behaviour.
"""
from __future__ import annotations

import functools
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map, under_x64
from repro.core.battery import TestEntry
from repro.rng.generators import x64
from repro.rng.sources import switch_block


def word_bucket(n: int) -> int:
    """The power-of-two bucket a job's bit block is generated at: the
    smallest power of two >= n (0 for an empty block). Bucketing bounds
    generated-but-unread words at <2x per job while keeping the number of
    distinct generation shapes (and so trace size) logarithmic in the
    spread of battery block sizes."""
    return 0 if n <= 0 else 1 << max(int(n) - 1, 0).bit_length()


def bucket_table(entries: List[TestEntry]):
    """``(sizes, bucket_ids)``: the sorted distinct power-of-two bucket
    sizes present in the job table, and each job's index into them."""
    sizes = sorted({word_bucket(e.n_words) for e in entries})
    index = {s: i for i, s in enumerate(sizes)}
    bids = np.asarray([index[word_bucket(e.n_words)] for e in entries],
                      np.int32)
    return sizes, bids


def generated_words(entries: List[TestEntry]) -> int:
    """Words the bucketed hot path generates for one pass over the table
    (each job pays its own bucket, not the battery-wide max)."""
    return sum(word_bucket(e.n_words) for e in entries)


def read_words(entries: List[TestEntry]) -> int:
    """Words the kernels actually consume in one pass over the table."""
    return sum(e.n_words for e in entries)


def block_ratio(entries: List[TestEntry]) -> float:
    """generated/read words under bucketing (1.0 = nothing wasted). The
    pre-bucketing hot path paid ``len(entries) * max_words`` instead."""
    r = read_words(entries)
    return generated_words(entries) / r if r else 1.0


def stream_table(entries: List[TestEntry]) -> np.ndarray:
    """Per-job generator stream ids. Identity for an unsplit battery;
    sub-jobs get ``group + n_groups * part`` — unique, deterministic, and
    independent of worker count or plan. An empty job table (a replan of
    nothing after elastic re-meshing) yields an empty table, not a
    ``max()`` crash."""
    if not entries:
        return np.zeros((0,), np.int32)
    n_groups = max(e.group for e in entries) + 1
    return np.asarray([e.group + n_groups * e.part for e in entries],
                      np.int32)


def _kernels(entries: List[TestEntry]):
    """The uniform kernel switch table: every test as ``bits ->
    (float32 stat, float32 p)`` — shared by the generator-switch job and
    the captured-buffer job so both dispatch paths score bits
    identically (the ingest parity guarantee)."""
    return [lambda bits, e=e: tuple(
        jnp.asarray(v, jnp.float32) for v in e.kernel(bits))
        for e in entries]


def _job_fn(entries: List[TestEntry], with_offset: bool = False,
            block_provider: Optional[Callable] = None):
    """(job_id, seed, gen_id[, offset]) -> (stat, p). job_id == -1 -> idle.

    ``with_offset=True`` adds a runtime stream-offset argument routed to
    the generator switch (campaign grids, ``make_grid_runner``); the
    default path traces exactly the classic three-argument job, so
    existing executables and trace counts are untouched.

    ``block_provider`` is the abstract bit-supply seam: any
    ``(gen_id, seed, stream, n[, offset]) -> uint32[n]`` traceable
    callable; the default is the registry-backed ``sources.switch_block``
    (the historical ``gen_block_by_id``). Captured sources never pass
    through here — they enter as prefetched buffers via
    ``make_external_runner``/``gather_captured_bits``.

    Generation is BUCKETED: jobs are grouped into power-of-two word
    buckets (``bucket_table``) and an inner ``lax.switch`` generates
    exactly the job's bucket — a 4k-word birthday job no longer pays for
    the battery-wide ``max_words`` block a 160k-word coupon/poker job
    needs (the block is zero-padded to the widest bucket so the kernel
    switch sees one static shape, but padding is a broadcast, not
    generator work). Idle slots (``job_id == -1``) take a zero-length
    sentinel path: the outer ``lax.cond`` returns ``(0, nan)`` directly,
    so a padded round pays neither generation NOR kernel work — no
    ``n_words`` zero block is ever materialized or routed through the
    kernel switch. Both the cond predicate and the switch indices are
    per-shard scalars, so the branches survive the fan-out vmap over
    generators as real branches, not selects."""
    provider = switch_block if block_provider is None else block_provider
    kernels = _kernels(entries)
    streams = jnp.asarray(stream_table(entries))
    sizes, bids = bucket_table(entries)
    bucket_ids = jnp.asarray(bids)
    n_max = sizes[-1] if sizes else 0

    def gen_branch(nb):
        def gen(seed, gen_id, stream, offset=None):
            with x64():
                block = provider(gen_id, seed, stream, nb, offset)
            if nb < n_max:
                block = jnp.concatenate(
                    [block, jnp.zeros((n_max - nb,), jnp.uint32)])
            return block
        return gen
    gen_branches = [gen_branch(nb) for nb in sizes]

    if with_offset:
        def run(job_id, seed, gen_id, offset):
            def idle(_):
                return jnp.float32(0.0), jnp.float32(jnp.nan)

            def work(ops):
                seed, gen_id, offset = ops
                j = jnp.clip(job_id, 0, len(entries) - 1)
                bits = jax.lax.switch(bucket_ids[j], gen_branches,
                                      seed, gen_id, streams[j], offset)
                return jax.lax.switch(j, kernels, bits)

            return jax.lax.cond(job_id < 0, idle, work,
                                (seed, gen_id, offset))

        return run

    def run(job_id, seed, gen_id):
        def idle(_):
            return jnp.float32(0.0), jnp.float32(jnp.nan)

        def work(ops):
            seed, gen_id = ops
            j = jnp.clip(job_id, 0, len(entries) - 1)
            bits = jax.lax.switch(bucket_ids[j], gen_branches,
                                  seed, gen_id, streams[j])
            return jax.lax.switch(j, kernels, bits)

        return jax.lax.cond(job_id < 0, idle, work, (seed, gen_id))

    return run


def make_round_runner(entries: List[TestEntry], mesh,
                      on_trace: Optional[Callable[[], None]] = None,
                      block_provider: Optional[Callable] = None):
    """Compiled fn: (round_assignment (W,), seed, gen_id) -> stats, ps (W,)."""
    job = _job_fn(entries, block_provider=block_provider)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P("workers"), P(), P()),
        out_specs=(P("workers"), P("workers")), check_vma=False)
    def round_fn(jobs, seed, gen_id):
        if on_trace is not None:
            on_trace()
        stat, p = job(jobs[0], seed, gen_id)
        return stat[None], p[None]

    return under_x64(jax.jit(round_fn))


def make_fanout_runner(entries: List[TestEntry], mesh,
                       on_trace: Optional[Callable[[], None]] = None,
                       block_provider: Optional[Callable] = None):
    """Multi-generator round: (round_assignment (W,), seeds (G,),
    gen_ids (G,)) -> stats, ps (G, W). The job is vmapped over the
    generator axis, so G generators are assessed in one device dispatch."""
    job = _job_fn(entries, block_provider=block_provider)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P("workers"), P(), P()),
        out_specs=(P(None, "workers"), P(None, "workers")), check_vma=False)
    def round_fn(jobs, seeds, gen_ids):
        if on_trace is not None:
            on_trace()
        stat, p = jax.vmap(lambda s, g: job(jobs[0], s, g))(seeds, gen_ids)
        return stat[:, None], p[:, None]

    return under_x64(jax.jit(round_fn))


def make_grid_runner(entries: List[TestEntry], mesh,
                     on_trace: Optional[Callable[[], None]] = None,
                     block_provider: Optional[Callable] = None):
    """Campaign-grid round: (round_assignment (W,), seeds (G,),
    gen_ids (G,), offsets (G,)) -> stats, ps (G, W). Like the fan-out
    runner but each lane of the vmapped cell axis also carries a runtime
    stream offset, so one executable serves every (generator, sub-stream)
    cell of a screening grid — wave after wave, knockout after knockout,
    no retrace (DESIGN.md §8)."""
    job = _job_fn(entries, with_offset=True, block_provider=block_provider)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P("workers"), P(), P(), P()),
        out_specs=(P(None, "workers"), P(None, "workers")), check_vma=False)
    def round_fn(jobs, seeds, gen_ids, offsets):
        if on_trace is not None:
            on_trace()
        stat, p = jax.vmap(lambda s, g, o: job(jobs[0], s, g, o))(
            seeds, gen_ids, offsets)
        return stat[:, None], p[:, None]

    return under_x64(jax.jit(round_fn))


def _external_job_fn(entries: List[TestEntry]):
    """(job_id, bits (n_max,)) -> (stat, p) — the captured-buffer twin of
    ``_job_fn``: no generator switch at all, the block arrives prefetched
    (``gather_captured_bits``). The kernel table, idle sentinel and
    clip-then-switch job routing are IDENTICAL to the generator path, so
    the same bits score the same p-values whichever door they enter by."""
    kernels = _kernels(entries)

    def run(job_id, bits):
        def idle(_):
            return jnp.float32(0.0), jnp.float32(jnp.nan)

        def work(bits):
            j = jnp.clip(job_id, 0, len(entries) - 1)
            return jax.lax.switch(j, kernels, bits)

        return jax.lax.cond(job_id < 0, idle, work, bits)

    return run


def make_external_runner(entries: List[TestEntry], mesh,
                         on_trace: Optional[Callable[[], None]] = None):
    """Captured-source round: (round_assignment (W,), bits (L, W, n_max))
    -> stats, ps (L, W). The lane axis L plays the role the ``gen_ids``
    axis plays in ``make_fanout_runner`` — one (source, seed, offset)
    cell per lane — but the bits are HOST-PREFETCHED buffers sharded over
    workers, not switch lanes: external bitstreams never join (or widen)
    the compiled generator switch, so screening a nonce dump can never
    retrace a generator battery and vice versa."""
    job = _external_job_fn(entries)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P("workers"), P(None, "workers", None)),
        out_specs=(P(None, "workers"), P(None, "workers")), check_vma=False)
    def round_fn(jobs, bits):
        if on_trace is not None:
            on_trace()
        stat, p = jax.vmap(lambda b: job(jobs[0], b))(bits[:, 0, :])
        return stat[:, None], p[:, None]

    return under_x64(jax.jit(round_fn))


def gather_captured_bits(entries: List[TestEntry], jobs, lanes) -> np.ndarray:
    """Host-side prefetch for ``make_external_runner``: a (L, W, n_max)
    uint32 buffer where slot ``[l, w]`` holds worker w's job block read
    from lane l's captured source — each job reads its power-of-two
    BUCKET (``bucket_table``) starting at the job's stream-table word
    offset within the lane's sub-stream, zero-padded to the widest
    bucket. Bucket sizing, stream ids and padding mirror ``_job_fn``
    exactly; that mirroring is what makes captured-vs-generator parity
    bitwise rather than approximate. ``lanes`` is a sequence of
    ``(source, seed, offset)`` cells (offset ``None`` = the canonical
    "no offset"); idle slots (job -1) stay zero and are never read."""
    streams = stream_table(entries)
    sizes, bids = bucket_table(entries)
    n_max = sizes[-1] if sizes else 0
    jobs = np.asarray(jobs, np.int64)
    out = np.zeros((len(lanes), len(jobs), n_max), np.uint32)
    for li, (source, seed, offset) in enumerate(lanes):
        for wi, j in enumerate(jobs):
            if j < 0:
                continue
            nb = sizes[bids[j]]
            out[li, wi, :nb] = source.block(seed, int(streams[j]), nb,
                                            offset)
    return out


def make_batch_runner(entries: List[TestEntry], mesh):
    """Whole-plan runner: (plan (R, W), seed, gen_id) -> (R, W) stats/ps.
    Single dispatch — used by benchmarks; the checkpointing driver prefers
    round-by-round."""
    job = _job_fn(entries)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P(None, "workers"), P(), P()),
        out_specs=(P(None, "workers"), P(None, "workers")), check_vma=False)
    def plan_fn(jobs, seed, gen_id):
        def body(_, jid):
            s, p = job(jid[0], seed, gen_id)
            return 0, (s, p)
        _, (stats, ps) = jax.lax.scan(body, 0, jobs)
        return stats[:, None], ps[:, None]

    return under_x64(jax.jit(plan_fn))


def inject_round_faults(injector, round_idx, row, arrays,  # repro: fault-boundary
                        deadline=None):
    """THE host-side fault-injection boundary (DESIGN.md §12, RPA106).

    Called by the driver in ``core/api.py`` strictly AFTER the compiled
    runner returned and materialised host numpy arrays, and strictly
    BEFORE the results are folded by ``stitch`` — the one point where a
    simulated eviction/corruption/straggle can touch results without
    the traced executables or their compile caches ever seeing it.
    ``arrays`` is the round's per-generator ``[(stats, ps), ...]``
    (each (W,)), mutated in place; returns ``(events, resize_to)``
    from :meth:`repro.core.faults.FaultInjector.apply_round`.

    Fault logic must never move inside a jitted/shard_mapped body:
    analysis rule RPA106 flags any injector call site in a traced
    context, and only this annotated host boundary is sanctioned.
    """
    return injector.apply_round(round_idx, np.asarray(row), arrays,
                                deadline=deadline)


def _entry_signature(e: TestEntry) -> tuple:
    """Structural identity of an entry for compile caching: everything
    ``_job_fn`` consumes. Registry-built kernels are a pure function of
    (kname, backend, params), so two ``build_battery`` calls with the
    same arguments key equal; entries carrying a custom callable (no
    kname) fall back to the callable's identity."""
    return (e.kname or id(e.kernel), e.params, e.backend, e.n_words,
            e.group, e.part)


_SEQ_RUNNERS: dict = {}


def run_sequential(entries: List[TestEntry], seed: int, gen_id: int):
    """Stock-TestU01 model: every test in order on ONE worker (baseline).

    The jitted pass is cached on the table's STRUCTURAL signature —
    repeated calls over equal job tables (seed sweeps, generator sweeps,
    fresh ``build_battery`` results) reuse one executable instead of
    re-tracing, the same compile-once discipline ``PoolSession`` applies
    to the pool runners."""
    key = tuple(_entry_signature(e) for e in entries)
    runner = _SEQ_RUNNERS.get(key)
    if runner is None:
        job = _job_fn(entries)

        @jax.jit
        def go(seed, gen_id):
            def body(_, jid):
                s, p = job(jid, seed, gen_id)
                return 0, (s, p)
            _, (stats, ps) = jax.lax.scan(
                body, 0, jnp.arange(len(entries), dtype=jnp.int32))
            return stats, ps

        runner = under_x64(go)
        if len(_SEQ_RUNNERS) >= 32:              # bound the executable pool
            _SEQ_RUNNERS.pop(next(iter(_SEQ_RUNNERS)))
        _SEQ_RUNNERS[key] = runner
    return runner(jnp.asarray(seed, jnp.int32),
                  jnp.asarray(gen_id, jnp.int32))

"""SPMD battery pool — the HTCondor pool mapped onto a device mesh.

One compiled program covers the whole battery: a worker's round executes
``lax.switch`` over the uniform job table (every test kernel has signature
``bits -> (stat, p)``), with the job's bit-stream derived from
``(seed, stream_table[job_id])`` — fresh-generator-per-test semantics
(paper §4.1). For a plain battery the stream table is the identity, so
results are bitwise those of the classic path; over-decomposed sub-jobs
get disjoint sub-streams (``group + n_groups * part``) that are stable
across pool width and schedule, which keeps hold/release and speculative
re-execution reconcilable.

Three compiled shapes, all pure functions of the job table (generator and
seed are runtime arguments — the same executable serves every generator,
which is what ``PoolSession``'s compile cache exploits):

  ``make_round_runner``   one round across workers via ``shard_map`` (the
                          paper's "submit a batch, wait for output files");
                          the host driver in ``core/api.py`` loops rounds so
                          progress is checkpointable between batches.
  ``make_fanout_runner``  the same round vmapped over a ``gen_ids`` axis —
                          G generators assessed in ONE dispatch (multi-
                          generator batteries, Wartel & Hill-style).
  ``make_batch_runner``   whole plan in one dispatch (benchmarks).

``on_trace`` (when given) fires once per trace of the round body; the
session uses it to assert/count cache behaviour.
"""
from __future__ import annotations

import functools
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map, under_x64
from repro.core.battery import TestEntry, max_words
from repro.rng.generators import gen_block_by_id, x64


def stream_table(entries: List[TestEntry]) -> np.ndarray:
    """Per-job generator stream ids. Identity for an unsplit battery;
    sub-jobs get ``group + n_groups * part`` — unique, deterministic, and
    independent of worker count or plan. An empty job table (a replan of
    nothing after elastic re-meshing) yields an empty table, not a
    ``max()`` crash."""
    if not entries:
        return np.zeros((0,), np.int32)
    n_groups = max(e.group for e in entries) + 1
    return np.asarray([e.group + n_groups * e.part for e in entries],
                      np.int32)


def _job_fn(entries: List[TestEntry], n_words: int):
    """(job_id, seed, gen_id) -> (stat, p). job_id == -1 -> idle.

    Idle slots skip generation entirely: the bit block is produced under
    a ``lax.cond``, so a padded round on a wide mesh pays nothing for its
    empty slots instead of generating (and discarding) a full ``n_words``
    block. The predicate is per-shard scalar, so the cond survives the
    fan-out vmap over generators as a real branch, not a select."""
    branches = [lambda bits, e=e: tuple(
        jnp.asarray(v, jnp.float32) for v in e.kernel(bits))
        for e in entries]
    branches.append(lambda bits: (jnp.float32(0.0), jnp.float32(jnp.nan)))
    streams = jnp.asarray(stream_table(entries))

    def run(job_id, seed, gen_id):
        stream = streams[jnp.clip(job_id, 0, len(entries) - 1)]

        def generate(_):
            with x64():
                return gen_block_by_id(gen_id, seed, stream, n_words)

        def idle(_):
            return jnp.zeros((n_words,), jnp.uint32)

        bits = jax.lax.cond(job_id < 0, idle, generate, None)
        idx = jnp.where(job_id < 0, len(entries), job_id)
        return jax.lax.switch(jnp.clip(idx, 0, len(entries)), branches, bits)

    return run


def make_round_runner(entries: List[TestEntry], mesh,
                      on_trace: Optional[Callable[[], None]] = None):
    """Compiled fn: (round_assignment (W,), seed, gen_id) -> stats, ps (W,)."""
    n_words = max_words(entries)
    job = _job_fn(entries, n_words)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P("workers"), P(), P()),
        out_specs=(P("workers"), P("workers")), check_vma=False)
    def round_fn(jobs, seed, gen_id):
        if on_trace is not None:
            on_trace()
        stat, p = job(jobs[0], seed, gen_id)
        return stat[None], p[None]

    return under_x64(jax.jit(round_fn))


def make_fanout_runner(entries: List[TestEntry], mesh,
                       on_trace: Optional[Callable[[], None]] = None):
    """Multi-generator round: (round_assignment (W,), seeds (G,),
    gen_ids (G,)) -> stats, ps (G, W). The job is vmapped over the
    generator axis, so G generators are assessed in one device dispatch."""
    n_words = max_words(entries)
    job = _job_fn(entries, n_words)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P("workers"), P(), P()),
        out_specs=(P(None, "workers"), P(None, "workers")), check_vma=False)
    def round_fn(jobs, seeds, gen_ids):
        if on_trace is not None:
            on_trace()
        stat, p = jax.vmap(lambda s, g: job(jobs[0], s, g))(seeds, gen_ids)
        return stat[:, None], p[:, None]

    return under_x64(jax.jit(round_fn))


def make_batch_runner(entries: List[TestEntry], mesh):
    """Whole-plan runner: (plan (R, W), seed, gen_id) -> (R, W) stats/ps.
    Single dispatch — used by benchmarks; the checkpointing driver prefers
    round-by-round."""
    n_words = max_words(entries)
    job = _job_fn(entries, n_words)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P(None, "workers"), P(), P()),
        out_specs=(P(None, "workers"), P(None, "workers")), check_vma=False)
    def plan_fn(jobs, seed, gen_id):
        def body(_, jid):
            s, p = job(jid[0], seed, gen_id)
            return 0, (s, p)
        _, (stats, ps) = jax.lax.scan(body, 0, jobs)
        return stats[:, None], ps[:, None]

    return under_x64(jax.jit(plan_fn))


def run_sequential(entries: List[TestEntry], seed: int, gen_id: int):
    """Stock-TestU01 model: every test in order on ONE worker (baseline)."""
    n_words = max_words(entries)
    job = _job_fn(entries, n_words)

    @jax.jit
    def go(seed, gen_id):
        def body(_, jid):
            s, p = job(jid, seed, gen_id)
            return 0, (s, p)
        _, (stats, ps) = jax.lax.scan(
            body, 0, jnp.arange(len(entries), dtype=jnp.int32))
        return stats, ps

    return under_x64(go)(jnp.asarray(seed, jnp.int32),
                         jnp.asarray(gen_id, jnp.int32))

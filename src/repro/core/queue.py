"""Classic functional battery driver — now a thin shim over the session API.

``run_battery(battery, gen, seed, mesh, ...)`` survives for callers that
think in strings and kwargs; it builds the equivalent declarative
``RunSpec``, submits it to a throwaway ``PoolSession``, and drives the
handle to completion. Everything the old driver did by hand — plan,
dispatch rounds, fold + checkpoint, hold/release, stitch (the paper's
master/makesub/condor_submit/empty/condor_release/superstitch loop) —
lives in ``repro.core.api`` now. Use that module directly when you want
the compile cache across runs, multi-generator fan-out, or streaming
per-round results.
"""
from __future__ import annotations

from typing import Optional, Union

from repro.core.api import (  # noqa: F401  (RunResult re-exported for compat)
    BatteryResult,
    PoolSession,
    RunResult,
    RunSpec,
)
from repro.core.policies import RetryPolicy, SchedulePolicy


def run_battery(battery: str, gen: str, seed: int, mesh,
                scale: float = 1.0,
                mode: Union[str, SchedulePolicy] = "lpt",
                checkpoint_path: Optional[str] = None,
                max_retries: int = 2, progress: bool = False) -> RunResult:
    """Run one battery for one generator on ``mesh`` and return its
    stitched ``RunResult`` (the classic one-call surface; see the module
    docstring for what it delegates to)."""
    spec = RunSpec(battery, generators=(gen,), seeds=(seed,), scale=scale,
                   policy=mode, retry=RetryPolicy(max_retries=max_retries),
                   checkpoint_path=checkpoint_path, progress=progress)
    return PoolSession(mesh=mesh).submit(spec).result()

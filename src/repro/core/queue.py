"""Battery driver — the paper's `master` script as a Python API.

Lifecycle per run (mirrors master/makesub/condor_submit/empty/release/
superstitch, paper §9 + Appendix A):

  1. plan      = make_plan(costs, W)          (makesub)
  2. per round: dispatch round_runner          (condor_submit, one batch)
  3. fold results, checkpoint progress         (empty + checkpoint)
  4. held = invalid/missing results -> replan  (condor_release)
  5. stitch report                             (superstitch)

Restart: if a progress checkpoint exists, completed tests are not re-run —
only the missing bitmap is scheduled (Condor standard-universe checkpoint
semantics at the plan level). Deterministic (seed, test_id) streams make
re-execution and speculative duplicates bitwise reconcilable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.ckpt import io as ckpt_io
from repro.core import stitch
from repro.core.battery import build_battery
from repro.core.pool import make_round_runner
from repro.core.scheduler import make_plan, replan
from repro.rng.generators import GEN_IDS


@dataclasses.dataclass
class RunResult:
    results: Dict[int, tuple]
    report: str
    rounds_run: int
    retries: int
    wall_s: float
    plan_rounds: int


def run_battery(battery: str, gen: str, seed: int, mesh,
                scale: float = 1.0, mode: str = "lpt",
                checkpoint_path: Optional[str] = None,
                max_retries: int = 2, progress: bool = False) -> RunResult:
    t0 = time.time()
    entries = build_battery(battery, scale)
    n_workers = mesh.devices.size
    costs = [e.cost for e in entries]

    results: Dict[int, tuple] = {}
    if checkpoint_path and ckpt_io.exists(checkpoint_path):
        idx, st, pv = ckpt_io.load_flat(checkpoint_path)
        results = {int(i): (float(s), float(p))
                   for i, s, p in zip(idx, st, pv)}

    todo = stitch.missing(results, len(entries))
    runner = make_round_runner(entries, mesh)
    gen_id = np.int32(GEN_IDS[gen])
    rounds_run = 0
    retries = 0
    plan_rounds = 0

    while todo and retries <= max_retries:
        plan = (make_plan(costs, n_workers, mode) if len(todo) == len(entries)
                and not retries else replan(todo, costs, n_workers, mode))
        plan_rounds = plan_rounds or plan.rounds
        for r in range(plan.rounds):
            row = np.asarray(plan.assignment[r], np.int32)
            stats, ps = runner(row, np.int32(seed), gen_id)
            results = stitch.fold(row[None, :], np.asarray(stats)[None, :],
                                  np.asarray(ps)[None, :], results)
            rounds_run += 1
            if checkpoint_path:
                idx = np.array(sorted(results), np.int32)
                st = np.array([results[i][0] for i in idx], np.float64)
                pv = np.array([results[i][1] for i in idx], np.float64)
                ckpt_io.save(checkpoint_path, [idx, st, pv])
            if progress:
                done = len(entries) - len(stitch.missing(results,
                                                         len(entries)))
                print(f"  round {rounds_run}: {done}/{len(entries)} "
                      f"files generated", flush=True)
        held = stitch.missing(results, len(entries))
        if held:
            retries += 1                              # condor_release
            if progress:
                print(f"  {len(held)} held tests released for retry")
        todo = held

    rep = stitch.report(entries, results, gen, seed)
    return RunResult(results, rep, rounds_run, retries, time.time() - t0,
                     plan_rounds)

"""Battery definitions: SmallCrush (10), Crush (96), BigCrush (106).

Mirrors TestU01's structure: a battery is an ordered list of ENTRIES, each a
fixed parameterization of one of the ten test kernels (stats/tests.py).
Crush/BigCrush re-use the same kernels at more/larger parameter points —
exactly how TestU01's batteries relate (paper §3.1). ``scale`` lets the same
battery run laptop-sized (CI) or pod-sized.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List

from repro.stats import backends as B

# relative per-word cost weights (scan-heavy kernels cost more per word)
KERNEL_WEIGHT = {
    "birthday": 1.0, "collision": 1.0, "gap": 1.2, "poker": 1.0,
    "coupon": 6.0, "maxoft": 1.0, "weight": 0.6, "rank": 8.0,
    "hamcorr": 0.6, "serial2d": 0.8, "pairstream": 0.6,
}

# Historical discriminating power per kernel, seeded from the known-bad
# generators (rng/generators.py: RANDU and MINSTD shift their 31-bit state
# left, so bit 0 is constant — the bit-level kernels annihilate them:
# weight/rank give p = 0, hamcorr p ~ 1e-27, while the distributional
# kernels barely notice at CI scales). The adaptive schedule policy ranks
# jobs by DISCRIMINATION/cost, so a cheap killer like `weight` lands in
# round one and a bad generator is failed long before `coupon` or `rank`
# would have been dispatched. Static by design — the table is part of the
# battery definition, not of any one run's history (DESIGN.md §3).
DISCRIMINATION = {
    "weight": 1.0, "rank": 1.0, "hamcorr": 0.8,
    "birthday": 0.3, "serial2d": 0.3, "collision": 0.2,
    "gap": 0.15, "maxoft": 0.15, "poker": 0.1, "coupon": 0.05,
    # pairstream is a machinery check (seam disjointness), not a quality
    # test — any signal at all is a hard failure, so it screens first
    "pairstream": 1.0,
}


def discrimination(entry: "TestEntry") -> float:
    """Discriminating power of a battery entry (0 when kname is unknown —
    synthetic/test entries schedule by cost alone)."""
    return DISCRIMINATION.get(entry.kname, 0.0)


@dataclasses.dataclass(frozen=True)
class TestEntry:
    index: int                  # position in the pool's job table
    name: str
    kernel: Callable            # bits -> (stat, p)
    n_words: int                # uint32 words consumed
    cost: float                 # scheduler cost estimate
    kname: str = ""             # kernel family (enables re-parameterization)
    params: tuple = ()          # sorted (key, value) kernel kwargs
    group: int = -1             # original battery test index (== index
    #                             unless this entry is a sub-job)
    part: int = 0               # sub-job position within its group
    n_parts: int = 1            # group size (1 = not decomposed)
    backend: str = "reference"  # kernel backend the callable is bound to
    #                             (stats/backends.py registry)

    def __post_init__(self):
        if self.group < 0:
            object.__setattr__(self, "group", self.index)


_WORDS = {
    "birthday": lambda k: k.get("n", 4096),
    "collision": lambda k: k.get("n", 65536),
    "gap": lambda k: k.get("n", 65536),
    "poker": lambda k: k.get("n", 32768) * 5,
    "coupon": lambda k: k.get("n", 65536),
    "maxoft": lambda k: k.get("n", 16384) * k.get("t", 8),
    "weight": lambda k: k.get("n", 65536),
    "rank": lambda k: k.get("n_mats", 1024) * 32,
    "hamcorr": lambda k: k.get("n", 65536),
    "serial2d": lambda k: k.get("n", 65536) * 2,
    "pairstream": lambda k: k.get("n", 32768) * 2,
}


def _mk(index, kname, scale, backend="reference", **kw):
    fn = B.get_kernel(kname, backend)
    words = _WORDS[kname](kw)
    name = kname + ("" if not kw else "_" + "_".join(
        f"{a}{v}" for a, v in sorted(kw.items())))
    return TestEntry(index, name, functools.partial(fn, **kw), words,
                     words * KERNEL_WEIGHT[kname] * scale,
                     kname=kname, params=tuple(sorted(kw.items())),
                     backend=backend)


_BASE = [  # SmallCrush: one instance of each kernel (explicit params so
    # `scale` applies; kernel defaults restated)
    ("birthday", dict(n=4096, tbits=30)), ("collision", dict(n=65536, kbits=26)),
    ("gap", dict(n=65536, beta=0.125)), ("poker", dict(n=32768)),
    ("coupon", dict(n=65536, d=8)), ("maxoft", dict(n=16384, t=8)),
    ("weight", dict(n=65536)), ("rank", dict(n_mats=1024)),
    ("hamcorr", dict(n=65536)), ("serial2d", dict(n=65536, d=64)),
]

# Crush/BigCrush parameter grids (per kernel). Sizes scale with `scale`.
_VARIANTS = {
    # (n, tbits) pairs keep lambda = n^3/4k in 2..128 (Poisson regime)
    "birthday": [dict(n=1024, tbits=26), dict(n=2048, tbits=28),
                 dict(n=2048, tbits=30), dict(n=4096, tbits=30),
                 dict(n=8192, tbits=30), dict(n=4096, tbits=28),
                 dict(n=1024, tbits=24), dict(n=2048, tbits=26),
                 dict(n=2048, tbits=24)],
    "collision": [dict(n=n, kbits=k) for n in (32768, 65536, 131072)
                  for k in (24, 26, 28)],
    "gap": [dict(n=n, beta=b) for n in (32768, 65536, 131072)
            for b in (0.0625, 0.125, 0.25)],
    "poker": [dict(n=n) for n in (16384, 32768, 65536, 131072)],
    "coupon": [dict(n=n, d=d) for n in (32768, 65536) for d in (4, 8, 16)],
    "maxoft": [dict(n=n, t=t) for n in (8192, 16384, 32768)
               for t in (4, 8, 16)],
    "weight": [dict(n=n) for n in (32768, 65536, 131072, 262144)],
    "rank": [dict(n_mats=m) for m in (512, 1024, 2048, 4096)],
    "hamcorr": [dict(n=n) for n in (32768, 65536, 131072, 262144)],
    "serial2d": [dict(n=n, d=d) for n in (32768, 65536, 131072)
                 for d in (16, 64, 128)],
}


def _scaled(kw, kname, scale):
    import math
    kw = dict(kw)
    orig_n = kw.get("n", 0)
    for key in ("n", "n_mats"):
        if key in kw:
            kw[key] = max(int(kw[key] * scale), 256)
    if kname == "birthday" and "n" in kw:
        # keep the Poisson rate lambda = n^3/4k invariant under scaling;
        # if tbits clamps, re-solve n from the target lambda instead
        lam0 = orig_n ** 3 / (4.0 * (1 << kw.get("tbits", 30)))
        tb = kw.get("tbits", 30) + round(3 * math.log2(max(scale, 1e-9)))
        kw["tbits"] = min(max(tb, 16), 30)
        n = int(round((lam0 * 4 * (1 << kw["tbits"])) ** (1 / 3)))
        # Poisson validity needs lambda << n; when tbits clamps hard the
        # re-solved n can leave lambda ~ n (the duplicate-spacing count
        # stops being Poisson and every generator skews p -> 1). Cap n at
        # sqrt(k)/2 so lambda = n^3/4k <= n/16 always holds.
        n = min(n, int(math.sqrt(1 << kw["tbits"]) / 2))
        kw["n"] = max(n, 128)
    if kname == "collision" and "n" in kw:
        # keep lambda = n^2/2k invariant (collision count regime)
        kb = kw.get("kbits", 26) + round(2 * math.log2(max(scale, 1e-9)))
        kw["kbits"] = min(max(kb, 14), 30)
    return kw


# The stream-seam battery (campaign subsystem, DESIGN.md §8): four
# pairstream variants over ONE shared block size, so every entry reads
# the same 2n-word window and the campaign can align all of them on the
# same adjacent-stream seam (rng.generators.seam_offsets). Modes probe
# different failure shapes of the offset machinery: float correlation,
# bit-level correlation, exact duplication, off-by-k seams.
_PAIRSTREAM = [
    ("pairstream", dict(n=32768, mode="corr")),
    ("pairstream", dict(n=32768, mode="hamcorr")),
    ("pairstream", dict(n=32768, mode="match")),
    ("pairstream", dict(n=32768, mode="shift")),
]


def build_battery(name: str, scale: float = 1.0,
                  backend: str = "reference") -> List[TestEntry]:
    """Battery job table. ``backend`` selects the kernel implementation
    family-wide (stats/backends.py): "reference", "accelerated", or
    "auto" (resolved here, so the table records a concrete backend)."""
    backend = B.resolve(backend)
    if name == "smallcrush":
        combos = [(k, _scaled(kw, k, scale)) for k, kw in _BASE]
    elif name == "pairstream":
        combos = [(k, _scaled(kw, k, scale)) for k, kw in _PAIRSTREAM]
    elif name in ("crush", "bigcrush"):
        target = 96 if name == "crush" else 106
        combos = []
        pools = {k: list(v) for k, v in _VARIANTS.items()}
        order = list(_VARIANTS)
        i = 0
        while len(combos) < target:
            k = order[i % len(order)]
            if pools[k]:
                combos.append((k, _scaled(pools[k].pop(0), k, scale)))
            i += 1
            if i > 10 * target:                  # pools exhausted -> rescale
                for k2 in order:
                    pools[k2] = [dict(kw, n=int(kw.get("n", 65536) * 2))
                                 if "n" in kw else kw
                                 for kw in _VARIANTS[k2]]
        combos = combos[:target]
    else:
        raise KeyError(name)
    return [_mk(i, k, scale, backend=backend, **kw)
            for i, (k, kw) in enumerate(combos)]


def max_words(entries: List[TestEntry]) -> int:
    """Widest bit-block any entry consumes; 0 for an empty table (an
    elastic replan of nothing must not raise)."""
    return max((e.n_words for e in entries), default=0)


def split_entry(entry: TestEntry, n_parts: int,
                start_index: int = 0) -> List[TestEntry]:
    """Over-decomposition: split one test into ``n_parts`` sub-jobs.

    Each sub-job is the same kernel re-parameterized lambda-invariantly at
    1/n_parts of the sample size (via ``_scaled``, so Poisson-regime tests
    keep their calibration) and draws its own disjoint generator sub-stream
    (see ``pool.stream_table``). The stitcher later folds the group's
    sub-p-values back into one verdict (Stouffer/Fisher combine).

    If the re-parameterization cannot actually shrink the test (parameter
    floors), the entry is returned unsplit — a sub-job as heavy as the
    original mitigates nothing.
    """
    if n_parts <= 1 or not entry.kname:
        return [dataclasses.replace(entry, index=start_index)]
    sub_kw = _scaled(dict(entry.params), entry.kname, 1.0 / n_parts)
    sub_words = _WORDS[entry.kname](sub_kw)
    if sub_words >= entry.n_words:                  # floors won: no shrink
        return [dataclasses.replace(entry, index=start_index)]
    fn = B.get_kernel(entry.kname, entry.backend or "reference")
    sub_cost = entry.cost * (sub_words / max(entry.n_words, 1))
    return [
        TestEntry(start_index + p,
                  f"{entry.name}[{p + 1}/{n_parts}]",
                  functools.partial(fn, **sub_kw), sub_words, sub_cost,
                  kname=entry.kname, params=tuple(sorted(sub_kw.items())),
                  group=entry.group, part=p, n_parts=n_parts,
                  backend=entry.backend)
        for p in range(n_parts)
    ]

"""Unified public API: declarative ``RunSpec`` -> compile-once
``PoolSession`` -> streaming ``BatteryRun``.

The paper's orchestration layer (`master`/`makesub`/`condor_submit`/
`empty`/`condor_release`/`superstitch`) as three first-class objects:

  ``RunSpec``      a frozen, declarative description of one run — battery,
                   scale, generator(s), seed(s), schedule policy, retry
                   policy, checkpoint path. One spec fully determines the
                   work; specs are hashable and comparable.
  ``PoolSession``  owns the device mesh and a compile cache keyed on
                   ``(battery, scale, n_workers, decomposition)``. The
                   compiled round program takes generator and seed as
                   runtime arguments, so repeated submits — different
                   generators, different seeds, replans after
                   hold/release — reuse the same jitted executable
                   instead of re-tracing. Pool width is a RUNTIME
                   property: ``resize(n)`` (and ``grow()``/``shrink()``
                   sugar — condor machines joining/vacating) swaps the
                   mesh, and live runs replan their remaining rounds
                   onto the new width at the next round boundary.
                   Executables for other widths stay cached — resizing
                   back is a cache hit, not a recompile (DESIGN.md §6).
  ``BatteryRun``   the submit handle, with HTCondor-shaped verbs:
                   ``poll()`` advances/reports one round, ``held()``
                   lists jobs with missing/invalid results, ``release()``
                   replans them, ``result()`` drives to completion,
                   ``stream()`` iterates per-round status, ``verdict()``
                   reports the sequential PASS/FAIL/UNDECIDED decision
                   after any round, ``cancel()`` drops pending rounds
                   (condor_rm). A spec with several generators fans out
                   in ONE dispatch per round (the job is vmapped over a
                   ``gen_ids`` axis).

Adaptive early stopping (DESIGN.md §3-§4): ``policy="adaptive"`` orders
rounds by discrimination/cost and ``stop_on_verdict=True`` auto-cancels
work for a generator the moment the sequential verdict engine declares
it definitively failed — in a multi-generator fan-out the failed
generator drops out of the vmapped ``gen_ids`` axis on subsequent
rounds, and once every generator is decided the remaining plan is never
dispatched.

Typical use::

    session = PoolSession()
    spec = RunSpec("smallcrush", generators=("splitmix64", "pcg32"),
                   seeds=(7,), scale=0.25)
    result = session.submit(spec).result()
    print(result.runs["pcg32"].report)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, List, Optional, Tuple, Union

import numpy as np

from repro.ckpt import io as ckpt_io
from repro.core import stitch
from repro.core.battery import TestEntry, build_battery
from repro.core.faults import (CorruptResultError, FaultEvent, FaultInjector,
                               FaultPlan, WorkerHealth)
from repro.core.policies import (RetryBudgetExhausted, RetryPolicy,
                                 SchedulePolicy, get_policy)
from repro.core.pool import (gather_captured_bits, inject_round_faults,
                             make_external_runner, make_fanout_runner,
                             make_grid_runner, make_round_runner)
from repro.core.scheduler import make_plan, replan
from repro.rng.sources import (BitSource, registry_size,
                               require_offsetable, resolve_source)
from repro.stats import backends as kernel_backends

# Battery presets (the folded BatteryConfig from common/config.py):
# test count and the sample-size multiplier of the paper-sized run.
# "pairstream" is the stream-seam machinery check the campaign subsystem
# runs as its screening phase (DESIGN.md §8), not a TestU01 analogue.
BATTERY_SIZES = {"smallcrush": 10, "crush": 96, "bigcrush": 106,
                 "pairstream": 4}
DEFAULT_SCALES = {"smallcrush": 1.0, "crush": 4.0, "bigcrush": 16.0,
                  "pairstream": 1.0}


def emit_progress(progress: Union[bool, Callable], msg: str) -> None:
    """The single progress choke point for the drive machinery.

    ``progress`` is a ``RunSpec.progress`` value: ``False`` drops the
    line, ``True`` prints it to stdout (the interactive CLI), and a
    callable receives it — which is how daemon and ``--json`` runs keep
    stdout clean while still logging (``release()`` used to ``print``
    with no way to redirect the sink).
    """
    if not progress:
        return
    if callable(progress):
        progress(msg)
    else:
        print(msg, flush=True)


# ---------------------------------------------------------------------------
# RunSpec


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Declarative description of one battery run.

    ``generators`` may be a single name or a tuple; ``seeds`` broadcasts
    (one seed shared by every generator) or pairs element-wise.

    ``alpha`` is the family-wise error rate the sequential verdict engine
    spends across the battery (stitch.sequential_verdict);
    ``stop_on_verdict=True`` cancels pending work for a generator as soon
    as its verdict is definitive.

    ``verdict_engine`` picks WHICH engine judges the interim looks
    (stitch.VERDICT_ENGINES): ``"bonferroni"`` is the classic
    Bonferroni-sequential spending rule; ``"evalue"`` is the anytime-
    valid e-process engine (core/evidence.py, DESIGN.md §13) that FAILs
    when calibrated e-value wealth reaches ``1/alpha`` and records a
    wealth trajectory per generator. Both share alpha and the verdict
    surface, so everything downstream (checkpoints, campaigns, serve,
    CLI) is engine-agnostic.

    ``backend`` selects the test-kernel implementation family-wide
    (stats/backends.py): "reference" (pure-jnp), "accelerated" (Pallas
    kernels) or "auto" (accelerated on real TPU hardware, reference under
    interpret/CPU). Both backends share one ``bits -> (stat, p)``
    contract and stitch identical verdicts (tests/test_backends.py).

    ``offsets`` (campaign grids, DESIGN.md §8) gives each generator
    position a word offset into its (seed, stream) sequences: position g
    reads words ``[offsets[g], offsets[g] + n)`` instead of ``[0, n)``.
    ``None`` (the default) is the classic path with untouched trace
    shapes; any tuple — even all zeros — routes dispatch through the
    offset-taking grid runner, whose executables are shared across every
    offset value. Non-zero offsets require counter-based (offset-
    continuable) sources; ``mwc`` has no jump-ahead and is refused.

    ``sources`` is the BitSource spelling of the run's bit supply
    (rng/sources.py): a tuple of ``BitSource`` objects or declarative
    specs (``"pcg32"``, ``"file:capture.npy"``, a ``CapturedSource``).
    ``generators=`` remains the back-compat spelling — names resolve to
    ``GeneratorSource``s — and after construction BOTH fields are
    populated (``generators`` holds each source's reporting name), so
    every consumer that keys results by ``spec.generators[g]`` is
    untouched. Captured sources dispatch as prefetched host buffers,
    never as switch lanes (DESIGN.md §11).

    ``progress`` is ``False`` (silent), ``True`` (print to stdout) or a
    callable sink — every progress line the drive machinery emits goes
    through ``emit_progress``, so daemons can log without touching
    stdout.

    ``inject`` is an optional ``faults.FaultPlan`` (DESIGN.md §12):
    a seeded-deterministic schedule of simulated pool faults — evict,
    corrupt, straggle, lose_worker — applied at the host-side runner
    boundary (``pool.inject_round_faults``), so compiled executables
    and trace caches are untouched and the run replays bit-for-bit."""
    battery: str
    generators: Union[str, Tuple[str, ...]] = ()
    seeds: Union[int, Tuple[int, ...]] = (0,)  # repro: runtime-arg
    scale: float = 1.0
    policy: Union[str, SchedulePolicy] = "lpt"
    retry: RetryPolicy = RetryPolicy()  # repro: runtime-arg
    checkpoint_path: Optional[str] = None  # repro: runtime-arg
    progress: Union[bool, Callable] = False  # repro: runtime-arg
    alpha: float = 0.01  # repro: runtime-arg
    stop_on_verdict: bool = False  # repro: runtime-arg
    verdict_engine: str = "bonferroni"  # repro: runtime-arg
    backend: str = "auto"
    offsets: Optional[Union[int, Tuple[int, ...]]] = None
    sources: Optional[Tuple] = None
    inject: Optional[FaultPlan] = None  # repro: runtime-arg

    def __post_init__(self):
        if self.battery not in BATTERY_SIZES:
            raise KeyError(f"unknown battery {self.battery!r}; "
                           f"known: {sorted(BATTERY_SIZES)}")
        if self.sources is not None:
            given = (self.sources if isinstance(self.sources, (tuple, list))
                     else (self.sources,))
            srcs = tuple(resolve_source(s) for s in given)
            if not srcs:
                raise ValueError("sources must name at least one source")
            gens = tuple(s.name for s in srcs)
        else:
            gens = ((self.generators,) if isinstance(self.generators, str)
                    else tuple(self.generators))
            if not gens:
                gens = ("splitmix64",)
            srcs = tuple(resolve_source(g) for g in gens)
        seeds = ((self.seeds,) if isinstance(self.seeds, int)
                 else tuple(int(s) for s in self.seeds))
        if len(seeds) == 1:
            seeds = seeds * len(gens)
        if len(seeds) != len(gens):
            raise ValueError(
                f"{len(seeds)} seeds for {len(gens)} generators "
                "(give one seed, or one per generator)")
        object.__setattr__(self, "generators", gens)
        object.__setattr__(self, "seeds", seeds)
        object.__setattr__(self, "sources", srcs)
        if self.offsets is not None:
            offs = ((int(self.offsets),) if isinstance(self.offsets, int)
                    else tuple(int(o) for o in self.offsets))
            if len(offs) == 1:
                offs = offs * len(gens)
            if len(offs) != len(gens):
                raise ValueError(
                    f"{len(offs)} offsets for {len(gens)} generators "
                    "(give one offset, or one per generator)")
            for s, o in zip(srcs, offs):
                if o < 0:
                    raise ValueError(f"offsets must be >= 0, got {o}")
                require_offsetable(s, o)         # typed, single gate
            object.__setattr__(self, "offsets", offs)
        get_policy(self.policy)                  # validate early
        if not (0.0 < self.alpha < 1.0):
            raise ValueError(f"alpha must be in (0, 1), got {self.alpha}")
        stitch.verdict_for(self.verdict_engine)  # validate early
        if self.backend not in kernel_backends.BACKENDS:
            raise KeyError(f"unknown backend {self.backend!r}; "
                           f"known: {kernel_backends.BACKENDS}")
        if self.inject is not None and not isinstance(self.inject, FaultPlan):
            raise TypeError(f"inject must be a faults.FaultPlan, "
                            f"got {type(self.inject)}")

    @classmethod
    def preset(cls, battery: str, **overrides) -> "RunSpec":
        """Paper-sized spec for a battery (scale from DEFAULT_SCALES)."""
        overrides.setdefault("scale", DEFAULT_SCALES[battery])
        return cls(battery, **overrides)

    @property
    def n_tests(self) -> int:
        """Battery size in TEST space (pre-decomposition)."""
        return BATTERY_SIZES[self.battery]

    @property
    def n_generators(self) -> int:
        """Width of the fan-out axis (generator positions)."""
        return len(self.generators)

    @property
    def switch_lanes(self) -> int:
        """Minimum compiled-switch width this spec's generator-backed
        sources need: ``1 + max(gen_id)`` over the non-captured sources
        (0 when every source is captured). ``PoolSession._runner`` keys
        executables on it, so a generator registered after a switch was
        traced reuses nothing narrower than its own lane — and specs
        confined to built-in lanes keep sharing the executables they
        always shared."""
        ids = [s.gen_id for s in self.sources if not s.captured]
        return 1 + max(ids) if ids else 0

    @property
    def captured_positions(self) -> Tuple[int, ...]:
        """Source positions dispatched via the prefetched-buffer path
        (``CapturedSource``) rather than the compiled generator switch."""
        return tuple(g for g, s in enumerate(self.sources) if s.captured)


# ---------------------------------------------------------------------------
# results


@dataclasses.dataclass
class RunResult:
    """Per-generator outcome (the classic run_battery return shape)."""
    results: Dict[int, tuple]       # test index -> (stat, p), combined
    report: str
    rounds_run: int
    retries: int
    wall_s: float
    plan_rounds: int
    verdict: Optional[stitch.Verdict] = None    # sequential decision

    @property
    def n_suspect(self) -> int:
        """Tests flagged by the two-sided suspect rule."""
        return self.report.count("SUSPECT")


@dataclasses.dataclass
class BatteryResult:
    """Outcome of a (possibly multi-generator) submit."""
    spec: RunSpec
    runs: Dict[str, RunResult]      # generator name -> result
    rounds_run: int
    retries: int
    wall_s: float

    @property
    def n_suspect(self) -> int:
        """Suspect count across every generator's run."""
        return sum(r.n_suspect for r in self.runs.values())

    @property
    def verdicts(self) -> Dict[str, stitch.Verdict]:
        """Per-generator sequential verdicts, keyed by name."""
        return {g: r.verdict for g, r in self.runs.items()}


# ---------------------------------------------------------------------------
# checkpoint layout (v5: job-id keyed, worker-count independent,
# source-identity pinned, verdict-engine aware)

CKPT_VERSION = 5


@dataclasses.dataclass
class Checkpoint:
    """On-disk battery progress — v5, keyed by JOB ID, never by
    (round, worker) position. The layout is a pure function of the job
    table, so a checkpoint written on a W=8 mesh resumes bitwise on W=4
    (or any width) after elastic re-meshing (DESIGN.md §6).

    Wire layouts (``ckpt/io`` leaves)::

      v5 (written): [version, job_idx (K,), stats (G, K), ps (G, K),
                     decisions (G,) int8 — empty when absent, rounds_run,
                     alpha — nan when absent, source_uids (G,) bytes —
                     empty when absent, engine (1,) bytes,
                     log_wealth (G,) float64 — empty when absent]
      v4 (read):    v5 without the trailing engine + log_wealth leaves
      v3 (read):    v4 without the trailing source_uids leaf
      v2 (read):    [job_idx, stats, ps, decisions, rounds_run]
      v1 (read):    [job_idx, stats, ps]    (stats flat for one generator)

    Loading a v1..v4 file works transparently; the next save upgrades
    it to v5. ``decisions`` carries the verdict codes (see
    ``BatteryRun._DECISION_CODE``); ``None`` means no verdict state.
    ``alpha`` records which error rate the decisions were computed
    under — a resuming run adopts them only when its own alpha matches
    (they are a pure function of (results, alpha)). ``engine`` names the
    verdict engine that produced the decisions (v1..v4 files imply
    ``"bonferroni"``); resuming verdict state under a DIFFERENT engine
    raises ``VerdictEngineMismatch`` — the engines' decisions are not
    comparable. ``log_wealth`` snapshots each generator's accumulated
    e-process wealth under the ``evalue`` engine (DESIGN.md §13); it is
    advisory (wealth is recomputed from results on load) but makes the
    trajectory inspectable on disk. ``source_uids`` pins each generator
    position's BitSource identity (``BitSource.uid()``): for captured
    sources the uid embeds the file's content digest, so a checkpoint
    written against one capture REFUSES to resume against a re-captured
    (byte-different) file."""
    job_idx: np.ndarray                         # (K,) int32 job ids
    stats: np.ndarray                           # (G, K) float64
    ps: np.ndarray                              # (G, K) float64
    decisions: Optional[np.ndarray] = None      # (G,) int8 verdict codes
    rounds_run: int = 0
    alpha: Optional[float] = None               # decisions' error rate
    source_uids: Optional[np.ndarray] = None    # (G,) bytes BitSource.uid
    engine: str = "bonferroni"                  # decisions' verdict engine
    log_wealth: Optional[np.ndarray] = None     # (G,) float64 e-wealth
    version: int = CKPT_VERSION

    @property
    def n_generators(self) -> int:
        """Rows of the stacked (G, K) result arrays."""
        return int(self.stats.shape[0])

    @classmethod
    def load(cls, path: str) -> "Checkpoint":
        """Read any supported layout (v1..v5) into the v5 shape."""
        leaves = ckpt_io.load_flat(path)
        if len(leaves) == 10:                   # v5: verdict engine
            (ver, idx, st, pv, dec, rounds, alpha, uids, eng, lw) = leaves
            if int(ver) != CKPT_VERSION:
                raise ValueError(
                    f"checkpoint {path} declares version {int(ver)}; "
                    f"this build reads v1..v{CKPT_VERSION}")
            dec = np.asarray(dec, np.int8)
            alpha = float(alpha)
            uids = np.asarray(uids)
            eng = np.asarray(eng)
            lw = np.asarray(lw, np.float64)
            return cls(np.asarray(idx, np.int32), np.atleast_2d(st),
                       np.atleast_2d(pv), dec if dec.size else None,
                       int(rounds),
                       None if np.isnan(alpha) else alpha,
                       uids if uids.size else None,
                       engine=(bytes(eng.reshape(-1)[0]).decode()
                               if eng.size else "bonferroni"),
                       log_wealth=lw if lw.size else None,
                       version=CKPT_VERSION)
        if len(leaves) == 8:                    # v4: source identity
            ver, idx, st, pv, dec, rounds, alpha, uids = leaves
            if int(ver) != 4:
                raise ValueError(
                    f"checkpoint {path} declares version {int(ver)} in an "
                    f"8-leaf (v4) layout; this build reads "
                    f"v1..v{CKPT_VERSION}")
            dec = np.asarray(dec, np.int8)
            alpha = float(alpha)
            uids = np.asarray(uids)
            return cls(np.asarray(idx, np.int32), np.atleast_2d(st),
                       np.atleast_2d(pv), dec if dec.size else None,
                       int(rounds),
                       None if np.isnan(alpha) else alpha,
                       uids if uids.size else None, version=4)
        if len(leaves) == 7:                    # v3: no source identity
            ver, idx, st, pv, dec, rounds, alpha = leaves
            if int(ver) != 3:
                raise ValueError(
                    f"checkpoint {path} declares version {int(ver)} in a "
                    f"7-leaf (v3) layout; this build reads "
                    f"v1..v{CKPT_VERSION}")
            dec = np.asarray(dec, np.int8)
            alpha = float(alpha)
            return cls(np.asarray(idx, np.int32), np.atleast_2d(st),
                       np.atleast_2d(pv), dec if dec.size else None,
                       int(rounds),
                       None if np.isnan(alpha) else alpha, None, version=3)
        if len(leaves) == 5:                    # v2: verdict state present
            idx, st, pv, dec, rounds = leaves
            return cls(np.asarray(idx, np.int32), np.atleast_2d(st),
                       np.atleast_2d(pv),
                       np.atleast_1d(np.asarray(dec, np.int8)),
                       int(rounds), None, None, version=2)
        if len(leaves) == 3:                    # v1: classic results-only
            idx, st, pv = leaves
            return cls(np.asarray(idx, np.int32), np.atleast_2d(st),
                       np.atleast_2d(pv), None, 0, None, None, version=1)
        raise ValueError(
            f"checkpoint {path} has {len(leaves)} leaves; expected 3 (v1), "
            f"5 (v2), 7 (v3), 8 (v4) or 10 (v{CKPT_VERSION})")

    def save(self, path: str) -> None:
        """Write the v5 layout (whatever version was loaded)."""
        dec = (np.zeros((0,), np.int8) if self.decisions is None
               else np.asarray(self.decisions, np.int8))
        uids = (np.zeros((0,), "S1") if self.source_uids is None
                else np.asarray(self.source_uids))
        lw = (np.zeros((0,), np.float64) if self.log_wealth is None
              else np.asarray(self.log_wealth, np.float64))
        ckpt_io.save(path, [
            np.int64(CKPT_VERSION), np.asarray(self.job_idx, np.int32),
            np.atleast_2d(np.asarray(self.stats, np.float64)),
            np.atleast_2d(np.asarray(self.ps, np.float64)),
            dec, np.int64(self.rounds_run),
            np.float64(np.nan if self.alpha is None else self.alpha),
            uids, np.asarray([self.engine.encode()]), lw])

    def drop(self, job_ids) -> "Checkpoint":
        """A copy with the given jobs knocked out (simulated node loss /
        checkpoint surgery). Verdict state is discarded — decisions are a
        function of the full result set, and a resumed run recomputes
        them from what survives."""
        keep = ~np.isin(self.job_idx, np.asarray(list(job_ids), np.int32))
        return dataclasses.replace(
            self, job_idx=self.job_idx[keep], stats=self.stats[:, keep],
            ps=self.ps[:, keep], decisions=None, log_wealth=None,
            version=CKPT_VERSION)

    def results(self) -> List[Dict[int, tuple]]:
        """Per-generator {job_id: (stat, p)} — the in-memory form."""
        return [{int(i): (float(s), float(p))
                 for i, s, p in zip(self.job_idx, self.stats[g], self.ps[g])}
                for g in range(self.n_generators)]


# ---------------------------------------------------------------------------
# campaign spec + ledger (generator-fleet screening, DESIGN.md §8)


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """Declarative screening grid: ``generators`` x ``n_streams``
    sub-stream offsets, screened in ``waves`` (battery scales, run
    cheapest first) with failed cells knocked out of subsequent waves.

    ``waves`` are battery scales; the campaign driver sorts them
    ascending so the cheap screening waves run before the expensive
    confirmation waves (``scheduler.wave_schedule``). ``stream_check``
    prepends the pairstream seam battery as phase 0 — the inter-stream
    disjointness/correlation check over adjacent sub-streams.

    ``span`` is the word spacing between adjacent sub-streams (stream s
    of a cell reads words ``[s * span, ...)`` of every job's sequence);
    ``None`` derives the smallest power-of-two span that keeps every
    job's block of the largest wave inside its own stream. More than one
    stream requires every source to be offset-continuable
    (``counter_based`` — mwc is refused up front, not at dispatch).

    ``sources`` is the BitSource spelling of the fleet (mirrors
    ``RunSpec.sources``): BitSource objects or declarative specs,
    captured files included — a campaign can screen a nonce dump's
    sub-streams next to in-repo generators. ``generators=`` remains the
    back-compat spelling; after construction both fields are populated
    (``generators`` holds reporting names).

    ``verdict_engine`` mirrors ``RunSpec.verdict_engine``: under
    ``"evalue"`` every cell accumulates e-process wealth across waves in
    the ledger and is knocked out when wealth reaches ``1/alpha``
    (DESIGN.md §13). ``continue_band`` is the optional-continuation
    band: a cell that finishes the last scheduled wave UNDECIDED with
    wealth in ``[continue_band/alpha, 1/alpha)`` is *re-opened* — a
    fresh continuation phase over previously unread stream words is
    appended instead of force-deciding the cell — up to
    ``max_continuations`` times (0 disables; band 0 force-decides like
    the Bonferroni engine). Both knobs are inert under
    ``"bonferroni"``."""
    battery: str
    generators: Tuple[str, ...] = ()
    n_streams: int = 1
    seed: int = 0
    waves: Tuple[float, ...] = (0.25, 1.0)
    alpha: float = 0.01
    policy: Union[str, SchedulePolicy] = "lpt"
    retry: RetryPolicy = RetryPolicy()
    backend: str = "auto"
    stream_check: bool = True
    span: Optional[int] = None
    ledger_path: Optional[str] = None
    progress: Union[bool, Callable] = False
    sources: Optional[Tuple] = None
    verdict_engine: str = "bonferroni"
    continue_band: float = 0.5
    max_continuations: int = 1

    def __post_init__(self):
        if self.battery not in BATTERY_SIZES:
            raise KeyError(f"unknown battery {self.battery!r}; "
                           f"known: {sorted(BATTERY_SIZES)}")
        if self.sources is not None:
            given = (self.sources if isinstance(self.sources, (tuple, list))
                     else (self.sources,))
            srcs = tuple(resolve_source(s) for s in given)
            gens = tuple(s.name for s in srcs)
        else:
            gens = ((self.generators,) if isinstance(self.generators, str)
                    else tuple(self.generators))
            srcs = tuple(resolve_source(g) for g in gens)
        if not gens:
            raise ValueError("a campaign needs at least one generator "
                             "(or source)")
        if len(set(gens)) != len(gens):
            raise ValueError(f"duplicate generators in {gens}")
        object.__setattr__(self, "generators", gens)
        object.__setattr__(self, "sources", srcs)
        if self.n_streams < 1:
            raise ValueError(f"n_streams must be >= 1, got {self.n_streams}")
        if self.n_streams > 1:
            bad = [s.name for s in srcs if not s.counter_based]
            if bad:
                raise ValueError(
                    f"stream grids need offset-continuable generators; "
                    f"{bad} are not COUNTER_BASED")
        waves = ((self.waves,) if isinstance(self.waves, (int, float))
                 else tuple(float(w) for w in self.waves))
        if not waves or any(w <= 0 for w in waves):
            raise ValueError(f"waves must be positive scales, got {waves}")
        object.__setattr__(self, "waves", waves)
        if not (0.0 < self.alpha < 1.0):
            raise ValueError(f"alpha must be in (0, 1), got {self.alpha}")
        get_policy(self.policy)
        if self.backend not in kernel_backends.BACKENDS:
            raise KeyError(f"unknown backend {self.backend!r}; "
                           f"known: {kernel_backends.BACKENDS}")
        if self.span is not None and self.span < 1:
            raise ValueError(f"span must be >= 1, got {self.span}")
        stitch.verdict_for(self.verdict_engine)  # validate early
        if not (0.0 <= self.continue_band < 1.0):
            raise ValueError(f"continue_band must be in [0, 1), "
                             f"got {self.continue_band}")
        if self.max_continuations < 0:
            raise ValueError(f"max_continuations must be >= 0, "
                             f"got {self.max_continuations}")
        if (self.verdict_engine != "bonferroni" and self.max_continuations
                and self.continue_band > 0.0):
            # continuation phases read fresh words past every stream's
            # scheduled block, which needs jump-ahead
            bad = [s.name for s in srcs if not s.counter_based]
            if bad:
                raise ValueError(
                    f"optional continuation needs offset-continuable "
                    f"generators; {bad} are not COUNTER_BASED (set "
                    f"max_continuations=0 or continue_band=0.0)")

    @property
    def cells(self) -> List[Tuple[str, int]]:
        """Grid cells in ledger order: (generator, stream) pairs."""
        return [(g, s) for g in self.generators
                for s in range(self.n_streams)]

    @property
    def cell_sources(self) -> List[Tuple[BitSource, int]]:
        """Grid cells in ledger order as (BitSource, stream) pairs — the
        source-resolved twin of ``cells`` the phase driver builds its
        ``RunSpec.sources`` from."""
        return [(src, s) for src in self.sources
                for s in range(self.n_streams)]

    @property
    def n_cells(self) -> int:
        """Grid size: generators x streams."""
        return len(self.generators) * self.n_streams

    def digest(self) -> int:
        """Deterministic uint64 identity of everything the campaign's
        DECISIONS depend on — battery, grid, seed, waves, alpha, policy,
        stream_check, span, and (for captured sources) the FILE CONTENT
        each cell screens: a re-captured file is a different campaign
        and refuses the old ledger. Generator-only campaigns fold
        exactly the pre-BitSource key, so their stored ledger digests
        still match; likewise the verdict engine (plus its continuation
        knobs) is folded only when non-default, so Bonferroni ledgers
        keep their historical digests while an e-value campaign can
        never resume — or be resumed by — a Bonferroni ledger. Stored in the ledger so a resume against a
        reconfigured campaign is refused instead of silently replaying
        decisions made under different settings. ``backend`` is
        deliberately excluded: both backends are parity-asserted to
        stitch identical verdicts (tests/test_backends.py), so a ledger
        may move between reference and accelerated hosts."""
        import hashlib
        policy = get_policy(self.policy)
        parts = (self.battery, self.generators, self.n_streams,
                 self.seed, self.waves, self.alpha, policy.name,
                 policy.signature(), self.stream_check, self.span)
        captured = tuple(s.uid() for s in self.sources if s.captured)
        if captured:
            parts = parts + (captured,)
        if self.verdict_engine != "bonferroni":
            # folded only when non-default so every pre-engine ledger
            # digest stays byte-identical (same pattern as captured uids)
            parts = parts + (("engine", self.verdict_engine,
                              self.continue_band, self.max_continuations),)
        key = repr(parts)
        return int.from_bytes(
            hashlib.sha256(key.encode()).digest()[:8], "big")


CAMPAIGN_LEDGER_VERSION = 3

# cell decision codes shared by the ledger and the campaign driver
# (0/1/2 match BatteryRun._DECISION_CODE; the phase axis is the ledger's)
CELL_UNDECIDED, CELL_PASS, CELL_FAIL = 0, 1, 2


@dataclasses.dataclass
class CampaignLedger:
    """On-disk campaign progress — keyed by CELL identity
    ``(gen_id, stream)``, never by wave order or grid position, the same
    discipline as the v3 run checkpoint (job-id keyed, §6): the layout
    is a pure function of the grid, so a ledger survives re-ordering of
    waves and resumes on any pool width.

    Wire layouts (``ckpt/io`` leaves)::

      v3 (written): [version, gen_ids (C,) int32, streams (C,) int32,
                     decisions (C,) int8, decided_phase (C,) int8
                     (-1 = undecided), phases_done, alpha,
                     spec_digest uint64, source_uids (C,) bytes,
                     log_wealth (C,) float64 — empty when absent,
                     engine (1,) bytes, continuations int64]
      v2 (read):    v3 without the trailing log_wealth + engine +
                    continuations leaves
      v1 (read):    v2 without the trailing source_uids leaf

    A v1/v2 ledger loads transparently; the next save upgrades it to v3.
    ``source_uids`` pins each cell's BitSource identity
    (``BitSource.uid()``; captured cells carry ``gen_id`` -1 plus a
    content-bearing uid, so a re-captured file refuses the ledger).
    ``decisions`` carries ``CELL_UNDECIDED/CELL_PASS/CELL_FAIL``;
    ``decided_phase`` records WHICH phase decided the cell (0 = stream
    check when enabled, then the waves in ascending-scale order, then
    any continuation phases). ``phases_done`` counts completed phases,
    so a resumed campaign re-enters the phase list exactly where it
    stopped; a phase interrupted mid-battery additionally resumes from
    its own per-phase run checkpoint (``<ledger>.phaseK``).
    ``log_wealth`` accumulates each cell's e-process wealth across
    phases under the ``evalue`` engine (DESIGN.md §13) — it is DECISION
    state, persisted with the decisions it feeds, which is what makes
    optional continuation resume-safe. ``engine`` names the verdict
    engine (v1/v2 files imply ``"bonferroni"``); ``continuations``
    counts how many continuation phases have been opened, so a resumed
    campaign reconstructs the exact phase list. ``spec_digest`` pins the
    full decision-relevant configuration (``CampaignSpec.digest``) —
    resuming with a different battery, waves, seed, alpha, policy,
    stream_check, span or verdict engine is refused, not silently
    replayed."""
    gen_ids: np.ndarray
    streams: np.ndarray
    decisions: np.ndarray
    decided_phase: np.ndarray
    phases_done: int = 0
    alpha: Optional[float] = None
    spec_digest: int = 0
    source_uids: Optional[np.ndarray] = None    # (C,) bytes BitSource.uid
    log_wealth: Optional[np.ndarray] = None     # (C,) float64 e-wealth
    engine: str = "bonferroni"                  # decisions' verdict engine
    continuations: int = 0                      # continuation phases opened
    version: int = CAMPAIGN_LEDGER_VERSION

    @staticmethod
    def _want_ids(spec: CampaignSpec):
        """The spec's grid as ledger columns: per-cell gen_id (-1 for a
        captured cell — it holds no switch lane) and stream index."""
        gids = [(-1 if src.captured else src.gen_id)
                for src, _ in spec.cell_sources]
        return (np.asarray(gids, np.int32),
                np.asarray([s for _, s in spec.cell_sources], np.int32))

    @classmethod
    def fresh(cls, spec: CampaignSpec) -> "CampaignLedger":
        """An all-undecided ledger for the spec's grid."""
        c = spec.n_cells
        gids, streams = cls._want_ids(spec)
        uids = np.asarray([src.uid().encode()
                           for src, _ in spec.cell_sources])
        return cls(gids, streams,
                   np.zeros((c,), np.int8), np.full((c,), -1, np.int8),
                   0, spec.alpha, spec.digest(), uids,
                   log_wealth=np.zeros((c,), np.float64),
                   engine=spec.verdict_engine)

    @classmethod
    def load(cls, path: str) -> "CampaignLedger":
        """Read (and version-check) a v1, v2 or v3 ledger file."""
        leaves = ckpt_io.load_flat(path)
        if len(leaves) == 12:                   # v3: verdict engine
            (ver, gids, streams, dec, phase, done, alpha, digest, uids,
             lw, eng, cont) = leaves
            if int(ver) != CAMPAIGN_LEDGER_VERSION:
                raise ValueError(
                    f"campaign ledger {path} declares version {int(ver)} "
                    f"in a 12-leaf layout; this build reads "
                    f"v1/v2/v{CAMPAIGN_LEDGER_VERSION}")
            uids = np.asarray(uids)
            alpha = float(alpha)
            lw = np.asarray(lw, np.float64)
            eng = np.asarray(eng)
            return cls(np.asarray(gids, np.int32),
                       np.asarray(streams, np.int32),
                       np.asarray(dec, np.int8), np.asarray(phase, np.int8),
                       int(done), None if np.isnan(alpha) else alpha,
                       int(np.uint64(digest)),
                       uids if uids.size else None,
                       log_wealth=lw if lw.size else None,
                       engine=(bytes(eng.reshape(-1)[0]).decode()
                               if eng.size else "bonferroni"),
                       continuations=int(cont),
                       version=CAMPAIGN_LEDGER_VERSION)
        if len(leaves) == 9:                    # v2: source identity
            ver, gids, streams, dec, phase, done, alpha, digest, uids = leaves
            if int(ver) != 2:
                raise ValueError(
                    f"campaign ledger {path} declares version {int(ver)} "
                    f"in a 9-leaf (v2) layout; this build reads "
                    f"v1/v2/v{CAMPAIGN_LEDGER_VERSION}")
            uids = np.asarray(uids)
            alpha = float(alpha)
            return cls(np.asarray(gids, np.int32),
                       np.asarray(streams, np.int32),
                       np.asarray(dec, np.int8), np.asarray(phase, np.int8),
                       int(done), None if np.isnan(alpha) else alpha,
                       int(np.uint64(digest)),
                       uids if uids.size else None, version=2)
        if len(leaves) == 8:                    # v1: no source identity
            ver, gids, streams, dec, phase, done, alpha, digest = leaves
            if int(ver) != 1:
                raise ValueError(
                    f"campaign ledger {path} declares version {int(ver)} "
                    f"in an 8-leaf (v1) layout; this build reads "
                    f"v1/v2/v{CAMPAIGN_LEDGER_VERSION}")
            alpha = float(alpha)
            return cls(np.asarray(gids, np.int32),
                       np.asarray(streams, np.int32),
                       np.asarray(dec, np.int8), np.asarray(phase, np.int8),
                       int(done), None if np.isnan(alpha) else alpha,
                       int(np.uint64(digest)), None, version=1)
        raise ValueError(f"campaign ledger {path} has {len(leaves)} "
                         "leaves; expected 8 (v1), 9 (v2) or 12 (v3)")

    def save(self, path: str) -> None:
        """Write the 12-leaf v3 cell-keyed wire layout (atomic)."""
        uids = (np.zeros((0,), "S1") if self.source_uids is None
                else np.asarray(self.source_uids))
        lw = (np.zeros((0,), np.float64) if self.log_wealth is None
              else np.asarray(self.log_wealth, np.float64))
        ckpt_io.save(path, [
            np.int64(CAMPAIGN_LEDGER_VERSION),
            np.asarray(self.gen_ids, np.int32),
            np.asarray(self.streams, np.int32),
            np.asarray(self.decisions, np.int8),
            np.asarray(self.decided_phase, np.int8),
            np.int64(self.phases_done),
            np.float64(np.nan if self.alpha is None else self.alpha),
            np.uint64(self.spec_digest), uids, lw,
            np.asarray([self.engine.encode()]),
            np.int64(self.continuations)])

    def matches(self, spec: CampaignSpec) -> bool:
        """Does this ledger describe exactly this campaign — same cells
        in the same order AND the same decision-relevant configuration
        (``CampaignSpec.digest``: battery, waves, seed, alpha, policy,
        stream_check, span, captured-file content)? A resumed campaign
        refuses otherwise — cell decisions are only meaningful for the
        campaign that made them. A v1 ledger (no stored uids) matches on
        the pre-BitSource columns alone; captured cells always carry
        uids, so the digest still refuses re-captured files."""
        want_g, want_s = self._want_ids(spec)
        if self.source_uids is not None:
            want_u = np.asarray([src.uid().encode()
                                 for src, _ in spec.cell_sources])
            uids = np.asarray(self.source_uids)
            if uids.shape != want_u.shape or not bool(np.all(uids == want_u)):
                return False
        return (self.gen_ids.shape == want_g.shape
                and bool(np.all(self.gen_ids == want_g))
                and bool(np.all(self.streams == want_s))
                and (self.alpha is None or self.alpha == spec.alpha)
                and self.engine == spec.verdict_engine
                and self.spec_digest == spec.digest())


# ---------------------------------------------------------------------------
# session + compile cache


@dataclasses.dataclass
class _Compiled:
    """One job-table slot: the width-INDEPENDENT battery/job tables plus
    the jitted runners, keyed ``(n_workers, n_generators)``. One table
    serves every pool width — job identity must never depend on width
    (that is what makes checkpoints and resizes reconcile, DESIGN.md §6)
    — so a resize adds runner entries, never a second table, and a live
    run's captured slot IS the slot every dispatch compiles against."""
    entries: List[TestEntry]        # original battery (test space)
    jobs: List[TestEntry]           # possibly decomposed (job space)
    costs: List[float]
    combine: str
    runners: dict       # (n_workers, G, grid, captured, lanes) -> jitted fn


class PoolSession:
    """Owns the mesh and the compile cache. Build one session, submit many
    specs; runs against the same ``(battery, scale, n_workers)`` share one
    jitted round program (generator/seed are runtime arguments).

    Pool width is a runtime property (the paper's opportunistic pool:
    machines join when idle, vacate when their owner returns) —
    ``resize``/``grow``/``shrink`` re-mesh mid-run. Each width owns its
    own mesh and cache entries, so bouncing 8 -> 4 -> 8 recompiles only
    the 4-wide program and returns to the 8-wide executables for free."""

    def __init__(self, mesh=None, n_workers: Optional[int] = None):
        if mesh is None:
            from repro.launch.mesh import make_pool_mesh
            mesh = make_pool_mesh(n_workers)
        self.mesh = mesh
        self._meshes: Dict[int, object] = {int(mesh.devices.size): mesh}
        self._cache: Dict[tuple, _Compiled] = {}
        self.trace_counts: Dict[tuple, int] = {}

    @property
    def n_workers(self) -> int:
        """Current pool width (a runtime property — see ``resize``)."""
        return int(self.mesh.devices.size)

    def resize(self, n_workers: int) -> int:
        """Elastic re-meshing: set the pool width to ``n_workers``.
        Live ``BatteryRun``s replan their remaining rounds onto the new
        width at their next round boundary (completed results, verdict
        state and sub-stream assignments are all width-independent, so
        nothing is lost or re-executed needlessly). Compiled programs
        for other widths stay cached. Returns the new width."""
        n = int(n_workers)
        if n < 1:
            raise ValueError(f"pool width must be >= 1, got {n}")
        if n != self.n_workers:
            mesh = self._meshes.get(n)
            if mesh is None:
                from repro.launch.mesh import make_pool_mesh
                mesh = make_pool_mesh(n)
                self._meshes[n] = mesh
            self.mesh = mesh
        return self.n_workers

    def grow(self, n: int = 1) -> int:
        """``n`` machines joined the pool (condor: owner went idle)."""
        return self.resize(self.n_workers + n)

    def shrink(self, n: int = 1) -> int:
        """``n`` machines vacated (condor: owner came back)."""
        return self.resize(self.n_workers - n)

    @property
    def total_traces(self) -> int:
        """Round-program traces so far (compile-cache accounting)."""
        return sum(self.trace_counts.values())

    def cache_key(self, spec: RunSpec) -> tuple:
        """Trace-accounting key: one entry per compiled pool width. The
        RESOLVED kernel backend is part of the key — reference and
        accelerated job tables compile different programs, while "auto"
        shares the slot of whatever it resolves to."""
        policy = get_policy(spec.policy)
        return (spec.battery, float(spec.scale), self.n_workers,
                policy.signature(), kernel_backends.resolve(spec.backend))

    def _table_key(self, spec: RunSpec) -> tuple:
        """Job-table key — deliberately WITHOUT the pool width: the table
        is a pure function of (battery, scale, decomposition, backend)."""
        policy = get_policy(spec.policy)
        return (spec.battery, float(spec.scale), policy.signature(),
                kernel_backends.resolve(spec.backend))

    def _compiled(self, spec: RunSpec) -> _Compiled:
        key = self._table_key(spec)
        hit = self._cache.get(key)
        if hit is None:
            entries = build_battery(spec.battery, spec.scale,
                                    backend=kernel_backends.resolve(
                                        spec.backend))
            policy = get_policy(spec.policy)
            # decompose is invoked WITHOUT the pool width: the job table
            # is shared across widths (checkpoint job ids and live runs
            # survive resize only because of that), so a width-dependent
            # decomposition is impossible by construction, not by
            # convention (SchedulePolicy protocol, DESIGN.md §6)
            jobs = policy.decompose(entries, None) or entries
            combine = getattr(policy, "combine", "stouffer")
            hit = _Compiled(entries, jobs, [j.cost for j in jobs],
                            combine, {})
            self._cache[key] = hit
        return hit

    def _runner(self, spec: RunSpec, n_gens: Optional[int] = None,
                captured: bool = False):
        """The jitted round program for this spec's shape: the current
        pool width x G generators. ``n_gens`` overrides the spec's width —
        adaptive runs shrink the vmapped gen_ids axis as failed generators
        drop out — and each (width, G) pair is its own cached executable,
        so resizing back to a width seen before recompiles nothing.
        Specs carrying ``offsets`` compile the grid runner (the offset is
        a runtime argument, so ONE executable serves every cell offset of
        a campaign — wave after wave, knockout after knockout).

        Runner slots also carry the SWITCH WIDTH an executable was traced
        at: a ``lax.switch`` clamps out-of-range indices, so dispatching
        a later-registered generator through a narrower switch would
        silently run the wrong lane. ``spec.switch_lanes`` states the
        width this dispatch needs; any cached executable at least that
        wide is reused (registering a 10th generator retraces NOTHING for
        the built-in nine), a wider need compiles a fresh, wider switch.
        ``captured=True`` selects the prefetched-buffer program
        (``make_external_runner``) — no generator switch at all."""
        key = self.cache_key(spec)
        compiled = self._compiled(spec)
        g = spec.n_generators if n_gens is None else n_gens
        grid = spec.offsets is not None and not captured
        need = 0 if captured else spec.switch_lanes
        rk = (self.n_workers, g, grid, captured, need)
        runner = compiled.runners.get(rk)
        if runner is None:
            for (w, gg, gr, cap, lanes), r in compiled.runners.items():
                if ((w, gg, gr, cap) == (self.n_workers, g, grid, captured)
                        and lanes >= need):
                    runner = r
                    break
        if runner is None:
            def on_trace():
                self.trace_counts[key] = self.trace_counts.get(key, 0) + 1
            if captured:
                runner = make_external_runner(compiled.jobs, self.mesh,
                                              on_trace=on_trace)
                lanes = 0
            else:
                make = (make_grid_runner if grid
                        else make_round_runner if g == 1
                        else make_fanout_runner)
                runner = make(compiled.jobs, self.mesh, on_trace=on_trace)
                lanes = registry_size()     # the switch traced THIS wide
            compiled.runners[(self.n_workers, g, grid, captured, lanes)] \
                = runner
        return runner

    def entries(self, spec: RunSpec) -> List[TestEntry]:
        """The spec's battery test table (test space, pre-decomposition) —
        what ``RunResult.results`` keys refer to."""
        return self._compiled(spec).entries

    def submit(self, spec: RunSpec) -> "BatteryRun":
        """condor_submit: plan the spec (resuming from its checkpoint if
        one exists) and hand back the run handle. Compilation is lazy —
        the first ``poll``/``result`` triggers it on a cache miss."""
        return BatteryRun(self, spec)


# ---------------------------------------------------------------------------
# run handle


class BatteryRun:
    """Streaming handle for one submitted spec (HTCondor verbs)."""

    def __init__(self, session: PoolSession, spec: RunSpec):
        self.session = session
        self.spec = spec
        self._compiled = session._compiled(spec)
        self._t0 = time.time()
        self.rounds_run = 0
        self.retries = 0
        self.driver_retries = 0
        self.plan_rounds = 0
        self.cancelled = False
        # fault domain (DESIGN.md §12): optional deterministic injector,
        # the event ledger, and the per-slot health/quarantine model —
        # all host-side, none of it visible to the compiled runners
        self._injector = (FaultInjector(spec.inject)
                          if spec.inject is not None else None)
        self.fault_events: List[FaultEvent] = []
        self.health = WorkerHealth()
        self.quarantines: List[dict] = []
        G = spec.n_generators
        self._results: List[Dict[int, tuple]] = [dict() for _ in range(G)]
        # verdict state under the spec's engine (stitch.VERDICT_ENGINES):
        # sticky per-generator decisions; a decided generator is dropped
        # from scheduling/dispatch when the spec asks for early stopping
        self._engine_fn = stitch.verdict_for(spec.verdict_engine)
        self._verdicts: List[stitch.Verdict] = [
            self._engine_fn({}, len(self._compiled.entries), spec.alpha)
            for _ in range(G)]
        # per-generator wealth trajectory, one sample per dispatched
        # round (evalue engine only — bonferroni has no wealth)
        self.wealth_history: List[List[float]] = [[] for _ in range(G)]
        self._restored_decisions: Optional[List[int]] = None
        self._restored_alpha: Optional[float] = None
        self._restored_engine: Optional[str] = None
        self._load_checkpoint()
        self._update_verdicts()
        if self._restored_decisions is not None:
            self._check_restored_verdicts()
        self._queue: List[np.ndarray] = []
        todo = self._missing()
        if todo:
            self._enqueue(todo, initial=True)

    # -- planning ----------------------------------------------------------

    def _active(self) -> List[int]:
        """Generator positions still being driven: everyone, minus the
        definitively-decided ones once ``stop_on_verdict`` is set."""
        if not self.spec.stop_on_verdict:
            return list(range(self.spec.n_generators))
        return [g for g in range(self.spec.n_generators)
                if not self._verdicts[g].decided]

    def _missing(self) -> List[int]:
        """Job-space HELD/missing set: union across ACTIVE generators
        (deterministic streams make duplicate re-execution for the others
        free; a verdict-decided generator stops contributing demand)."""
        n = len(self._compiled.jobs)
        held = set()
        for g in self._active():
            held.update(stitch.missing(self._results[g], n))
        return sorted(held)

    def _enqueue(self, todo: List[int], initial: bool = False) -> None:
        costs = self._compiled.costs
        jobs = self._compiled.jobs
        w = self.session.n_workers
        if initial and len(todo) == len(costs):
            plan = make_plan(costs, w, self.spec.policy, entries=jobs)
        else:
            plan = replan(todo, costs, w, self.spec.policy, entries=jobs)
        self.plan_rounds = self.plan_rounds or plan.rounds
        self._queue.extend(np.asarray(row, np.int32)
                           for row in plan.assignment)

    def _sync_width(self) -> None:
        """Elastic re-meshing: if the session was resized since this run's
        pending rounds were planned, replan the residual job set onto the
        new width at this round boundary. Completed results are untouched —
        job identity is width-independent (``pool.stream_table``), so the
        replan changes placement only, never which work remains."""
        w = self.session.n_workers
        if not self._queue or self._queue[0].shape[0] == w:
            return
        residual = sorted({int(j) for row in self._queue
                           for j in row if j >= 0})
        self._queue.clear()
        if residual:
            self._enqueue(residual)
            emit_progress(self.spec.progress,
                          f"  pool resized to {w} worker(s): {len(residual)} "
                          f"residual job(s) replanned onto "
                          f"{len(self._queue)} round(s)")

    # -- HTCondor verbs ----------------------------------------------------

    @property
    def pending_rounds(self) -> int:
        """Rounds still queued for dispatch."""
        return len(self._queue)

    @property
    def done(self) -> bool:
        """True when nothing is queued and no job is missing/held."""
        return not self._queue and not self._missing()

    def poll(self) -> dict:
        """Advance one round (one device dispatch covering every active
        generator) and report status — the paper's `master` polling
        `empty`. With ``stop_on_verdict`` each poll is also an interim
        look: decided generators leave the gen_ids axis, and the queue is
        dropped entirely once no generator remains undecided. A session
        ``resize()`` since the last poll is absorbed here: the residual
        rounds replan onto the new width before anything dispatches."""
        self._sync_width()
        self._auto_cancel()
        if self._queue:
            row = self._queue.pop(0)
            self._dispatch(row)
            self.rounds_run += 1
            self._update_verdicts()
            if self.spec.verdict_engine == "evalue":
                for g, v in enumerate(self._verdicts):
                    self.wealth_history[g].append(v.wealth)
            self._auto_cancel()
            self._save_checkpoint()
            if self.spec.progress:
                emit_progress(self.spec.progress,
                              f"  round {self.rounds_run}: "
                              f"{self._jobs_done()}/"
                              f"{len(self._compiled.jobs)} files generated")
        return self.status()

    def held(self) -> List[int]:
        """Job indices with missing/invalid results once the current plan
        is exhausted (paper: condor hold). A cancelled run holds nothing —
        its pending work is gone, not stuck."""
        return [] if (self._queue or self.cancelled) else self._missing()

    def verdict(self) -> Union[stitch.Verdict, Dict[str, stitch.Verdict]]:
        """The sequential verdict engine's current decision — a
        ``stitch.Verdict`` for a single-generator spec, else one per
        generator name. Valid after every round (Bonferroni-sequential
        spending, DESIGN.md §4), not just at completion."""
        self._update_verdicts()
        if self.spec.n_generators == 1:
            return self._verdicts[0]
        return {gen: self._verdicts[g]
                for g, gen in enumerate(self.spec.generators)}

    def results_by_position(self) -> List[Dict[int, tuple]]:
        """Combined TEST-space results per generator POSITION in the spec
        (sub-job groups folded back through the policy's combiner). The
        positional twin of ``verdicts_by_position`` — what the serve
        layer's demux slices a coalesced dispatch's results out of."""
        return [stitch.fold_groups(self._results[g], self._compiled.jobs,
                                   self._compiled.combine)
                for g in range(self.spec.n_generators)]

    def verdicts_by_position(self) -> List[stitch.Verdict]:
        """Interim verdicts indexed by generator POSITION in the spec.
        ``verdict()`` keys by name, which collapses a spec whose
        generators tuple repeats a name — exactly what a campaign grid
        does (one position per (generator, sub-stream) cell)."""
        self._update_verdicts()
        return list(self._verdicts)

    def cancel(self) -> int:
        """condor_rm: drop every pending round. Returns the number of
        rounds cancelled. Completed results (and the verdict state built
        from them) are kept; ``result()`` then finalizes immediately."""
        n = len(self._queue)
        self._queue.clear()
        self.cancelled = True
        self._save_checkpoint()
        return n

    def _check_restored_verdicts(self) -> None:
        """A v2 checkpoint's saved decisions must agree with the verdicts
        recomputed from its saved p-values — decisions are a pure function
        of results, so disagreement means the checkpoint was edited or
        written under a different alpha/battery."""
        if len(self._restored_decisions) != self.spec.n_generators:
            raise ValueError(
                f"checkpoint {self.spec.checkpoint_path} holds verdict "
                f"state for {len(self._restored_decisions)} generator(s), "
                f"spec has {self.spec.n_generators}")
        code = self._DECISION_CODE
        saved_alpha = self._restored_alpha
        for g, saved in enumerate(self._restored_decisions):
            if saved != code[self._verdicts[g].decision]:
                raise ValueError(
                    f"checkpoint {self.spec.checkpoint_path}: generator "
                    f"{self.spec.generators[g]!r} was saved as decision "
                    f"code {saved} (engine "
                    f"{self._restored_engine or self.spec.verdict_engine!r}, "
                    f"checkpoint alpha="
                    f"{'unrecorded' if saved_alpha is None else saved_alpha}"
                    f") but its saved results recompute to "
                    f"{self._verdicts[g].decision} under the spec's "
                    f"{self.spec.verdict_engine!r} engine at alpha="
                    f"{self.spec.alpha} — resumed with a different spec?")

    def _update_verdicts(self) -> None:
        """Recompute interim verdicts (test-space, after sub-job combine)
        under the spec's engine. Bonferroni decisions are sticky outright
        (a crossed boundary never un-crosses, so revisiting is pointless);
        evalue decisions are sticky only under ``stop_on_verdict``, where
        a decided generator's result set freezes — without early stopping
        wealth keeps moving as results land (e-values below 1 SHRINK it),
        and the final verdict must be the checkpoint-resumable pure
        function of the COMPLETE result set."""
        sticky = (self.spec.verdict_engine == "bonferroni"
                  or self.spec.stop_on_verdict)
        for g in range(self.spec.n_generators):
            if sticky and self._verdicts[g].decided:
                continue
            combined = stitch.fold_groups(self._results[g],
                                          self._compiled.jobs,
                                          self._compiled.combine)
            self._verdicts[g] = self._engine_fn(
                combined, len(self._compiled.entries), self.spec.alpha)

    def _auto_cancel(self) -> None:
        """stop_on_verdict: once every generator is decided, pending
        rounds are never dispatched."""
        if (self.spec.stop_on_verdict and self._queue
                and not self._active()):
            dropped = len(self._queue)
            self._queue.clear()
            self.cancelled = True
            emit_progress(self.spec.progress,
                          f"  verdict decided for all generators — "
                          f"{dropped} pending round(s) cancelled")

    def release(self) -> int:
        """condor_release: replan the HELD set. Returns #jobs released.

        A manual release is FREE with respect to the ``RetryPolicy``
        budget: ``retries`` counts every release pass (reporting truth),
        but the driver's own hold/release loop budgets against the
        separate ``driver_retries`` counter — a user who released once
        by hand does not get fewer automatic retries from ``result()``
        or ``stream()``."""
        h = self.held()
        if not h:
            return 0
        self.retries += 1
        self._enqueue(h)
        emit_progress(self.spec.progress,
                      f"  {len(h)} held tests released for retry")
        return len(h)

    def _driver_release(self) -> int:
        """A release initiated by the drive loop itself — the only kind
        that spends the ``RetryPolicy`` budget. Sleeps the policy's
        exponential backoff (``RetryPolicy.backoff_for``; 0.0 by
        default, so pre-existing drive loops stay sleepless) before
        replanning — the condor_release etiquette of not hammering a
        pool that is actively misbehaving."""
        delay = self.spec.retry.backoff_for(self.driver_retries)
        if delay > 0:
            emit_progress(self.spec.progress,
                          f"  backing off {delay:.2f}s before release "
                          f"pass {self.driver_retries + 1}")
            time.sleep(delay)
        self.driver_retries += 1
        return self.release()

    def drive(self, stop_when=None,
              raise_on_exhausted: bool = True) -> "BatteryRun":
        """The hold/release drive loop shared by ``result()``,
        ``stream()`` and the campaign phase driver: dispatch every queued
        round, then release-and-retry the HELD set until it clears or
        the ``RetryPolicy`` budget (driver-initiated releases only) is
        spent. ``stop_when`` is an optional ``handle -> bool`` predicate
        checked after every round; when it fires the remaining rounds
        are cancelled (the campaign uses it to stop a phase the moment
        every real cell's verdict is decided). Returns ``self``.

        Budget exhaustion with jobs still HELD raises
        ``RetryBudgetExhausted`` (carrying the final HELD job list)
        instead of silently finalising with missing results;
        ``raise_on_exhausted=False`` restores the old give-up behaviour
        for callers that treat a stalled run as data (the campaign
        phase driver, the serve daemon's failed-ticket path)."""
        while True:
            while self._queue:
                self.poll()
                if stop_when is not None and stop_when(self):
                    self.cancel()
                    break
            if self.done or self.cancelled:
                break
            held = self.held()
            if not held:
                break
            if self.driver_retries >= self.spec.retry.max_retries:
                if raise_on_exhausted:
                    raise RetryBudgetExhausted(held, self.driver_retries)
                break
            self._driver_release()
        return self

    def stream(self) -> Iterator[dict]:
        """Yield one status per round until the run completes — INCLUDING
        hold/release retry rounds, exactly like ``result()``'s drive
        loop, so a streaming client sees the retries instead of the
        stream ending silently while jobs are still HELD. Like
        ``drive()``, budget exhaustion with jobs still HELD raises
        ``RetryBudgetExhausted``."""
        while True:
            while self._queue:
                yield self.poll()
            if self.done or self.cancelled:
                return
            held = self.held()
            if not held:
                return
            if self.driver_retries >= self.spec.retry.max_retries:
                raise RetryBudgetExhausted(held, self.driver_retries)
            self._driver_release()

    def result(self) -> Union[RunResult, BatteryResult]:
        """Drive to completion (rounds + hold/release retries) and stitch.
        Returns ``RunResult`` for a single-generator spec, ``BatteryResult``
        otherwise."""
        return self.drive()._finalize()

    def status(self) -> dict:
        """One condor_q-shaped snapshot: state, job/round counters, the
        HELD set and the per-generator interim verdicts. Cancellation is
        STICKY: a cancelled run reports ``"cancelled"`` even when every
        job it executed happens to have completed (``done`` must not win
        the ladder — condor_rm'ing a finished queue is still a rm)."""
        state = ("cancelled" if self.cancelled
                 else "done" if self.done
                 else "running" if self._queue else "held")
        return {"state": state, "jobs_done": self._jobs_done(),
                "jobs_total": len(self._compiled.jobs),
                "pending_rounds": len(self._queue),
                "rounds_run": self.rounds_run, "retries": self.retries,
                "held": self.held(),
                "verdicts": {gen: self._verdicts[g].decision
                             for g, gen in enumerate(self.spec.generators)}}

    # -- execution ---------------------------------------------------------

    def _jobs_done(self) -> int:
        """Jobs with results for EVERY generator — reporting truth, not
        scheduling demand (_missing spans only active generators, so a
        cancelled generator's unexecuted jobs must not read as done)."""
        n = len(self._compiled.jobs)
        undone = set()
        for res in self._results:
            undone.update(stitch.missing(res, n))
        return n - len(undone)

    def _dispatch(self, row: np.ndarray) -> None:
        """One round's dispatches covering the ACTIVE generators. When
        early stopping has decided some of a fan-out's generators, the
        dispatch shrinks to the survivors — the vmapped gen_ids axis
        narrows, the failed generator's remaining tests are never
        executed. Positions backed by a ``CapturedSource`` dispatch
        through the prefetched-buffer program (their bits are gathered
        host-side from the memory-mapped capture), switch-backed
        positions through the classic generator switch — at most one
        device dispatch per family per round."""
        active = self._active()
        if not active:
            return
        srcs = self.spec.sources
        switched = [g for g in active if not srcs[g].captured]
        captured = [g for g in active if srcs[g].captured]
        per_gen = []
        if switched:
            runner = self.session._runner(self.spec, n_gens=len(switched))
            if self.spec.offsets is not None:
                seeds = np.asarray([self.spec.seeds[g] for g in switched],
                                   np.int32)
                gids = np.asarray([srcs[g].gen_id for g in switched],
                                  np.int32)
                offs = np.asarray([self.spec.offsets[g] for g in switched],
                                  np.int64)
                stats, ps = runner(row, seeds, gids, offs)
                stats, ps = np.asarray(stats), np.asarray(ps)
                per_gen += [(g, stats[a], ps[a])
                            for a, g in enumerate(switched)]
            elif len(switched) == 1:
                g0 = switched[0]
                stats, ps = runner(row, np.int32(self.spec.seeds[g0]),
                                   np.int32(srcs[g0].gen_id))
                per_gen.append((g0, np.asarray(stats), np.asarray(ps)))
            else:
                seeds = np.asarray([self.spec.seeds[g] for g in switched],
                                   np.int32)
                gids = np.asarray([srcs[g].gen_id for g in switched],
                                  np.int32)
                stats, ps = runner(row, seeds, gids)
                stats, ps = np.asarray(stats), np.asarray(ps)
                per_gen += [(g, stats[a], ps[a])
                            for a, g in enumerate(switched)]
        if captured:
            runner = self.session._runner(self.spec, n_gens=len(captured),
                                          captured=True)
            lanes = [(srcs[g], self.spec.seeds[g],
                      None if self.spec.offsets is None
                      else self.spec.offsets[g]) for g in captured]
            bits = gather_captured_bits(self._compiled.jobs, row, lanes)
            stats, ps = runner(row, bits)
            stats, ps = np.asarray(stats), np.asarray(ps)
            per_gen += [(g, stats[a], ps[a])
                        for a, g in enumerate(captured)]
        # ---- fault domain (DESIGN.md §12): everything below is host-side
        # post-processing of materialised numpy results — the compiled
        # runners above never see a fault, a gate, or a quarantine
        injected: List[FaultEvent] = []
        resize_to: Optional[int] = None
        if self._injector is not None:
            per_gen = [(g, np.array(st, np.float64), np.array(pv, np.float64))
                       for g, st, pv in per_gen]
            injected, resize_to = inject_round_faults(
                self._injector, self.rounds_run, row,
                [(st, pv) for _, st, pv in per_gen],
                deadline=self.spec.retry.deadline)
            self.fault_events.extend(injected)
            for ev in injected:
                emit_progress(self.spec.progress,
                              f"  fault[{ev.kind}] round {ev.round} "
                              f"slot {ev.slot} job {ev.job}: {ev.detail}")
        per_gen, gate_events = self._sanity_gate(row, per_gen, injected)
        if resize_to is not None and resize_to != self.session.n_workers:
            emit_progress(self.spec.progress,
                          f"  worker lost: pool resizes to {resize_to}")
            self.session.resize(resize_to)
        self._update_health(row, injected + gate_events)
        for g, st, pv in per_gen:
            self._results[g] = stitch.fold(row[None, :], st[None, :],
                                           pv[None, :], self._results[g])

    def _sanity_gate(self, row: np.ndarray, per_gen: list,
                     injected: List[FaultEvent]) -> tuple:
        """The result sanity gate: a non-idle slot whose stat or p is
        non-finite, or whose p falls outside [0, 1], is a corrupt
        result. It is nulled to NaN — so ``stitch.missing`` marks the
        job HELD and the retry machinery re-executes it — and recorded
        in the fault ledger as a ``corrupt_result`` event carrying the
        :class:`CorruptResultError` text. Silent corruption therefore
        becomes HELD+retry, never a wrong verdict. Slots an injected
        ``evict``/deadline-exceeded ``straggle`` already nulled this
        round are skipped (they are accounted faults, not corruption).
        Returns ``(per_gen, gate_events)``."""
        nulled = {ev.slot for ev in injected
                  if ev.kind == "evict"
                  or (ev.kind == "straggle" and "HELD" in ev.detail)}
        row = np.asarray(row)
        events: List[FaultEvent] = []
        out = []
        for g, st, pv in per_gen:
            st, pv = np.asarray(st), np.asarray(pv)
            bad = (row >= 0) & ~(np.isfinite(st) & np.isfinite(pv)
                                 & (pv >= 0.0) & (pv <= 1.0))
            for w in np.nonzero(bad)[0]:
                bad[w] = int(w) not in nulled
            if bad.any():
                st = np.array(st, np.float64)
                pv = np.array(pv, np.float64)
                for w in np.nonzero(bad)[0]:
                    err = CorruptResultError(
                        f"job {int(row[w])} (slot {int(w)}, generator "
                        f"position {g}) returned stat={float(st[w])!r} "
                        f"p={float(pv[w])!r}; p must be finite and in "
                        f"[0, 1] — result quarantined to HELD")
                    events.append(FaultEvent(
                        self.rounds_run, "corrupt_result", int(w),
                        int(row[w]), -1, str(err)))
                    emit_progress(self.spec.progress,
                                  f"  corrupt result gated: {err}")
                st[bad] = np.nan
                pv[bad] = np.nan
            out.append((g, st, pv))
        self.fault_events.extend(events)
        return out, events

    def _update_health(self, row: np.ndarray,
                       events: List[FaultEvent]) -> None:
        """Advance the per-slot health model with this round's outcome
        and quarantine flaky slots. Every non-idle slot either faulted
        (an injected evict/corrupt/straggle or a gated corrupt result
        landed on it) or ran clean; a slot whose consecutive-fault
        streak reaches ``RetryPolicy.quarantine_after`` is removed from
        the pool via the elastic ``resize`` path (floored at one
        worker), and its residual jobs replan onto the survivors at the
        next round boundary. After the re-mesh slot identities change,
        so all streaks reset."""
        faulted = {int(ev.slot) for ev in events if ev.slot >= 0}
        for w in range(row.shape[0]):
            if int(row[w]) >= 0:
                self.health.record(w, w in faulted)
        qa = self.spec.retry.quarantine_after
        if not qa:
            return
        flaky = self.health.flaky(qa)
        cur = self.session.n_workers
        if not flaky or cur <= 1:
            return
        new_w = max(1, cur - len(flaky))
        self.quarantines.append({"round": self.rounds_run,
                                 "slots": flaky, "workers": new_w})
        self.fault_events.append(FaultEvent(
            self.rounds_run, "quarantine", flaky[0], -1, -1,
            f"slot(s) {flaky} quarantined after {qa} consecutive "
            f"fault(s); pool shrinks to {new_w} worker(s)"))
        emit_progress(self.spec.progress,
                      f"  slot(s) {flaky} quarantined — pool shrinks "
                      f"to {new_w} worker(s)")
        self.health.reset()
        self.session.resize(new_w)

    # -- checkpointing -----------------------------------------------------

    _DECISION_CODE = {stitch.UNDECIDED: 0, stitch.PASS: 1, stitch.FAIL: 2}

    def _save_checkpoint(self) -> None:
        """Write the v5 layout: results keyed by JOB ID (never by the
        (round, worker) position of the dispatch that produced them), so
        the file is a pure function of the job table and resumes on any
        pool width. Verdict state always rides along — tagged with the
        engine that computed it, plus the per-generator wealth snapshot
        under the evalue engine; ``rounds_run`` is adopted on resume
        only by ``stop_on_verdict`` runs (their round count is part of
        the sequential-look bookkeeping)."""
        path = self.spec.checkpoint_path
        if not path:
            return
        idx = np.array(sorted(set().union(*[set(r) for r in self._results])),
                       np.int32)
        st = np.array([[r.get(int(i), (np.nan, np.nan))[0] for i in idx]
                       for r in self._results], np.float64)
        pv = np.array([[r.get(int(i), (np.nan, np.nan))[1] for i in idx]
                       for r in self._results], np.float64)
        decisions = np.array([self._DECISION_CODE[v.decision]
                              for v in self._verdicts], np.int8)
        uids = np.asarray([s.uid().encode() for s in self.spec.sources])
        lw = None
        if self.spec.verdict_engine == "evalue":
            lw = np.array([v.log_wealth for v in self._verdicts], np.float64)
        Checkpoint(idx, st, pv, decisions, self.rounds_run,
                   alpha=self.spec.alpha, source_uids=uids,
                   engine=self.spec.verdict_engine,
                   log_wealth=lw).save(path)

    def _load_checkpoint(self) -> None:
        path = self.spec.checkpoint_path
        if not (path and ckpt_io.exists(path)):
            return
        ck = Checkpoint.load(path)          # v1..v4 upgrade path lives here
        # Saved decisions are BINDING only for a stop_on_verdict run that
        # uses the SAME alpha they were computed under — there they drive
        # scheduling (decided generators are never re-enqueued) and the
        # round count is sequential-look bookkeeping, and the cross-check
        # catches tampering. Under any other (spec, alpha) they are
        # advisory: verdicts are a pure function of (results, alpha), so
        # the resumed run just recomputes them fresh. v2 files predate
        # the recorded alpha (ck.alpha is None) and keep their
        # documented refuse-on-mismatch behavior. Decisions made by a
        # DIFFERENT verdict engine are never comparable — not even
        # advisorily — so an engine mismatch on verdict-bearing state is
        # a typed refusal, not a silent recompute.
        if (ck.decisions is not None and self.spec.stop_on_verdict
                and ck.engine != self.spec.verdict_engine):
            raise stitch.VerdictEngineMismatch(
                f"checkpoint {path} holds verdict state computed by the "
                f"{ck.engine!r} engine (alpha="
                f"{'unrecorded' if ck.alpha is None else ck.alpha}) but "
                f"the spec resumes with verdict_engine="
                f"{self.spec.verdict_engine!r} (alpha={self.spec.alpha}) "
                f"— the engines' decisions are not comparable; re-run "
                f"from scratch or resume with the original engine")
        if (ck.decisions is not None and self.spec.stop_on_verdict
                and (ck.alpha is None or ck.alpha == self.spec.alpha)):
            self._restored_decisions = [int(d) for d in ck.decisions]
            self._restored_alpha = ck.alpha
            self._restored_engine = ck.engine
            self.rounds_run = ck.rounds_run
        if ck.n_generators != self.spec.n_generators:
            raise ValueError(
                f"checkpoint {path} holds {ck.n_generators} generator "
                f"row(s), spec has {self.spec.n_generators}")
        if ck.source_uids is not None:
            saved = [u.decode() for u in np.asarray(ck.source_uids)]
            want = [s.uid() for s in self.spec.sources]
            if saved != want:
                raise ValueError(
                    f"checkpoint {path} was written against sources "
                    f"{saved}, spec names {want} — for a captured source "
                    f"the uid embeds the file's content digest, so a "
                    f"re-captured (byte-different) file must re-run, "
                    f"never resume")
        if len(ck.job_idx) and int(np.max(ck.job_idx)) >= len(self._compiled.jobs):
            raise ValueError(
                f"checkpoint {path} references job {int(np.max(ck.job_idx))} "
                f"but this spec's job table has {len(self._compiled.jobs)} "
                "entries — it was written by a different battery/scale/"
                "decomposition")
        self._results = ck.results()

    # -- stitching ---------------------------------------------------------

    def _finalize(self) -> Union[RunResult, BatteryResult]:
        wall = time.time() - self._t0
        self._update_verdicts()
        per_pos = self.results_by_position()
        runs: Dict[str, RunResult] = {}
        for g, gen in enumerate(self.spec.generators):
            combined = per_pos[g]
            rep = stitch.report(self._compiled.entries, combined, gen,
                                self.spec.seeds[g])
            runs[gen] = RunResult(combined, rep, self.rounds_run,
                                  self.retries, wall, self.plan_rounds,
                                  verdict=self._verdicts[g])
        if self.spec.n_generators == 1:
            return runs[self.spec.generators[0]]
        return BatteryResult(self.spec, runs, self.rounds_run, self.retries,
                             wall)

"""The paper's orchestration system: session API, campaign screening,
schedule policies, stitching — see the per-module docstrings."""
# The paper's primary contribution — the orchestration SYSTEM.
# Public surface: RunSpec / PoolSession / BatteryRun (repro.core.api),
# campaign screening (repro.core.campaign), schedule + retry policies
# (repro.core.policies). The classic run_battery shim lives in
# repro.core.queue.
from repro.core.api import (  # noqa: F401
    BatteryResult,
    BatteryRun,
    CampaignLedger,
    CampaignSpec,
    Checkpoint,
    PoolSession,
    RunResult,
    RunSpec,
)
from repro.core.campaign import (  # noqa: F401
    Campaign,
    CampaignResult,
    screen,
)
from repro.core.evidence import (  # noqa: F401
    EvidenceVerdict,
    VerdictEngineMismatch,
    evidence_verdict,
)
from repro.core.faults import (  # noqa: F401
    CorruptResultError,
    FaultEvent,
    FaultInjector,
    FaultPlan,
    FaultRule,
    WorkerHealth,
)
from repro.core.policies import (  # noqa: F401
    POLICIES,
    RetryBudgetExhausted,
    RetryPolicy,
    SchedulePolicy,
    get_policy,
    register_policy,
)
from repro.core.stitch import (  # noqa: F401
    VERDICT_ENGINES,
    Verdict,
    sequential_verdict,
    verdict_for,
)

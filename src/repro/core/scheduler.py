"""Pool scheduler — the paper's batch model behind a policy registry.

The paper observed: K independent tests on W workers finish in ceil(K/W)
batches, each batch costing ~t_max (§11: 106 tests / 40 cores -> 3 batches
~= 11 min; 70 cores -> 2; 90 cores -> still 2). ``roundrobin`` reproduces
exactly that placement; ``lpt`` and ``over_decompose`` are the beyond-paper
schedulers. The actual placement algorithms now live in
``repro.core.policies`` as registered ``SchedulePolicy`` objects — this
module keeps the classic functional surface (``make_plan``/``replan``)
as a thin delegate for callers that think in mode strings.
"""
from __future__ import annotations

from typing import Sequence, Union

import numpy as np

from repro.core.policies import (  # noqa: F401  (re-exported for compat)
    Plan,
    SchedulePolicy,
    get_policy,
)


def make_plan(costs: Sequence[float], n_workers: int,
              mode: Union[str, SchedulePolicy] = "roundrobin",
              entries: Sequence = None) -> Plan:
    """Plan via the registered policy. When ``entries`` (the battery job
    table) is given and the policy defines ``plan_entries`` — the adaptive
    policy ranks by the entries' kernel discrimination, not just cost —
    the richer form is preferred; every other policy sees only costs."""
    policy = get_policy(mode)
    plan_entries = getattr(policy, "plan_entries", None)
    if entries is not None and plan_entries is not None:
        return plan_entries(entries, n_workers)
    return policy.plan(costs, n_workers)


def wave_schedule(scales: Sequence[float]) -> list:
    """Campaign wave order (DESIGN.md §8): the given battery scales
    sorted ASCENDING, duplicates preserved. Screening cheapest-first
    maximizes the knockout value of early waves — every cell a cheap
    wave kills never pays for the expensive confirmation waves — the
    same philosophy the adaptive policy applies at round level
    (discrimination/cost priority, §3) lifted to the campaign grid."""
    out = sorted(float(s) for s in scales)
    if not out:
        raise ValueError("a campaign needs at least one wave scale")
    if any(s <= 0 for s in out):
        raise ValueError(f"wave scales must be positive, got {out}")
    return out


def wave_makespan(costs: Sequence[float], n_workers: int, n_cells: int,
                  mode: Union[str, SchedulePolicy] = "lpt") -> tuple:
    """``(batched, per_cell)`` estimated makespans of one campaign wave
    over ``n_cells`` grid cells. Batched is the campaign's model — one
    plan whose round dispatches carry every cell on the vmapped cell
    axis, so the schedule is paid once; per-cell is the naive loop it
    replaces (the plan dispatched once per cell). The ratio is the
    batching win the campaign benchmark measures."""
    plan = make_plan(costs, n_workers, mode)
    return plan.est_makespan, plan.est_makespan * max(int(n_cells), 1)


def replan(missing: Sequence[int], costs: Sequence[float],
           n_workers: int, mode: Union[str, SchedulePolicy] = "lpt",
           entries: Sequence = None) -> Plan:
    """Plan covering only `missing` job indices (hold/release retry rounds,
    elastic re-meshing after worker loss, and adaptive resumes — the
    priority order is recomputed over just the still-missing entries).

    An empty ``missing`` set (every job already done when a resize
    triggers a replan) yields a zero-round plan — the run just completes,
    instead of the old ``ValueError: max() arg is an empty sequence``
    from the empty residual job table downstream."""
    missing = list(missing)
    if not missing:
        return Plan(np.zeros((0, n_workers), np.int32),
                    get_policy(mode).name, 0.0, 0.0)
    sub_entries = ([entries[i] for i in missing]
                   if entries is not None else None)
    sub = make_plan([costs[i] for i in missing], n_workers, mode,
                    entries=sub_entries)
    remap = np.asarray(list(missing) + [-1], np.int32)
    a = remap[np.where(sub.assignment >= 0, sub.assignment, len(missing))]
    return Plan(a.astype(np.int32), sub.mode, sub.est_makespan,
                sub.est_ideal)

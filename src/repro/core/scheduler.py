"""Pool scheduler — the paper's batch model, plus the LPT improvement.

The paper observed: K independent tests on W workers finish in ceil(K/W)
batches, each batch costing ~t_max (§11: 106 tests / 40 cores -> 3 batches
~= 11 min; 70 cores -> 2; 90 cores -> still 2). ``roundrobin`` reproduces
exactly that placement. ``lpt`` (longest-processing-time first) packs by the
per-test cost estimates and is the beyond-paper scheduler: same result
values (streams are order-independent), strictly better makespan whenever
test costs are skewed — which TestU01's are.

``over_decompose`` splits the heaviest tests' sample ranges into sub-jobs
(straggler mitigation at plan level; the stitcher folds sub-results).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Plan:
    assignment: np.ndarray          # (rounds, workers) int32 test index, -1 idle
    mode: str
    est_makespan: float             # sum over rounds of max worker cost
    est_ideal: float                # sum(costs)/W lower bound

    @property
    def rounds(self) -> int:
        return self.assignment.shape[0]


def make_plan(costs: Sequence[float], n_workers: int,
              mode: str = "roundrobin") -> Plan:
    k = len(costs)
    costs = np.asarray(costs, np.float64)
    if mode == "roundrobin":
        rounds = -(-k // n_workers)
        a = np.full((rounds, n_workers), -1, np.int32)
        for i in range(k):
            a[i // n_workers, i % n_workers] = i
    elif mode == "lpt":
        order = np.argsort(-costs)
        loads = np.zeros(n_workers)
        lists: List[List[int]] = [[] for _ in range(n_workers)]
        for i in order:
            w = int(np.argmin(loads))
            loads[w] += costs[i]
            lists[w].append(int(i))
        rounds = max(len(l) for l in lists)
        a = np.full((rounds, n_workers), -1, np.int32)
        for w, l in enumerate(lists):
            for r, i in enumerate(l):
                a[r, w] = i
    else:
        raise ValueError(mode)

    per_round = np.where(a >= 0, costs[np.clip(a, 0, None)], 0.0)
    est = float(per_round.max(axis=1).sum())
    return Plan(a, mode, est, float(costs.sum() / n_workers))


def replan(missing: Sequence[int], costs: Sequence[float],
           n_workers: int, mode: str = "lpt") -> Plan:
    """Plan covering only `missing` test indices (hold/release retry rounds,
    and elastic re-meshing after worker loss: same call, smaller W)."""
    sub = make_plan([costs[i] for i in missing], n_workers, mode)
    remap = np.asarray(list(missing) + [-1], np.int32)
    a = remap[np.where(sub.assignment >= 0, sub.assignment, len(missing))]
    return Plan(a.astype(np.int32), sub.mode, sub.est_makespan,
                sub.est_ideal)

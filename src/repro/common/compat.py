"""JAX version compatibility shims.

The repo targets the modern JAX surface (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``, scoped
``enable_x64`` inside traces). The pinned container ships jax 0.4.37,
where those spell differently — and where a scoped ``enable_x64`` inside
a jitted trace mis-lowers (u64 constants canonicalize to u32 at lowering
time, outside the context). Everything that needs to differ by version
lives here so the rest of the codebase writes against one API.
"""
from __future__ import annotations

import functools

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` (new) / ``jax.experimental.shard_map`` (<=0.4.x)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def make_mesh(axis_shapes, axis_names, devices=None):
    """``jax.make_mesh`` without the ``axis_types`` kwarg on old jax."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes, axis_names, devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names, devices=devices)


def under_x64(fn):
    """Call ``fn`` with the x64 context ambient for the WHOLE call —
    trace, lowering, and execution see one consistent dtype config.
    On jax<=0.4.x a scoped ``enable_x64`` that closes mid-trace truncates
    uint64 constants during lowering; entering it around the outer call
    (idempotent when already active) sidesteps that while keeping the
    scoped uses in traced code valid on newer jax."""
    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        with jax.experimental.enable_x64():
            return fn(*args, **kwargs)
    return wrapper

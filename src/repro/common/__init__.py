"""Shared infrastructure used across the battery system.

Only ``repro.common.compat`` (JAX version shims) is live; the growth
seed's LM model-config layer lives in ``repro.common.config`` and is
imported directly by its remaining consumers rather than re-exported
here — an eager re-export would drag the quarantined LM stack into the
battery import graph (see DESIGN.md §9 on the RPA501 reachability rule).
"""

from repro.common.config import (  # noqa: F401
    HW,
    HWConfig,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SHAPES,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
    pad_to,
    shape_applicable,
)

# repro: quarantine -- growth-seed LM model stack; exercised only by the seed tier-1 tests
"""Configuration dataclasses for CondorJAX.

``ModelConfig`` is the single source of truth for every assigned architecture;
``ShapeConfig`` describes one (seq_len, global_batch, kind) input-shape cell.
TestU01-style batteries (the paper's workload) are described by
``repro.core.api.RunSpec``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# helpers


def pad_to(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# model configuration


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0                 # routed experts
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared: int = 0                  # always-on shared experts (DeepSeek)
    d_ff_shared: int = 0
    first_dense_layers: int = 0        # leading dense layers (DeepSeek-V2: 1)
    d_ff_dense: int = 0                # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block config."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8               # 1 sLSTM per `slstm_every` blocks (7:1)
    proj_factor_m: float = 2.0         # mLSTM up-projection factor
    proj_factor_s: float = 4.0 / 3.0   # sLSTM FFN factor
    conv_width: int = 4
    chunk: int = 128                   # mLSTM chunkwise-parallel length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                        # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None     # default d_model // n_heads
    act: str = "silu"                  # silu (SwiGLU) | gelu (GeGLU) | gelu_plain | relu2
    gated_mlp: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False              # Chameleon
    rope: bool = True
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # gemma2
    attn_pattern: Tuple[str, ...] = ("global",)   # e.g. ("local","global")
    local_window: int = 4096
    attn_softcap: float = 0.0          # 0 disables
    final_softcap: float = 0.0
    query_scale: Optional[float] = None  # override 1/sqrt(head_dim)
    post_block_norm: bool = False      # gemma2 post-norms

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # hybrid (zamba2): one shared attn+MLP block applied every k ssm layers
    shared_attn_every: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0               # fixed encoder frame count (stub frontend)

    # modality stub: inputs are precomputed embeddings instead of token ids
    frontend: str = "tokens"           # tokens | frames (audio stub) | fused (vlm: ids)

    # numerics / memory knobs (per-arch presets; see DESIGN.md)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    adam_dtype: str = "float32"        # bf16 for the 340B preset (8-bit-Adam-style)
    remat_policy: str = "full"         # full | dots | none
    scan_group: int = 0                # 0 = single scan; else nested scan-of-scan
    train_accum: int = 1               # gradient-accumulation microbatches

    # ----- derived -----
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Megatron-style vocab padding for clean TP sharding."""
        return pad_to(self.vocab_size, 128)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic-history archs run the long_500k shape."""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (matches init_params; used for rooflines)."""
        from repro.models.lm import count_params  # late import, no jax needed
        return count_params(self)

    def n_active_params(self) -> int:
        from repro.models.lm import count_params
        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# input-shape cells


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                          # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a shape cell applies to an arch (with the reason for skips)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full-attention arch: 500k-history decode is out of family (see DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# battery (the paper's workload): described by repro.core.api.RunSpec —
# the old BatteryConfig/BATTERIES tables folded into RunSpec.preset().


# Roofline hardware constants (TPU v5e-class; see system brief).
@dataclasses.dataclass(frozen=True)
class HWConfig:
    peak_flops: float = 197e12         # bf16 FLOP/s per chip
    hbm_bw: float = 819e9              # bytes/s per chip
    ici_bw: float = 50e9               # bytes/s per link
    ici_links: int = 4                 # per chip on a 2D torus (used for roofline)
    hbm_bytes: float = 16e9


HW = HWConfig()

# repro: quarantine -- growth-seed sharding/elastic LM utilities; the battery pool has its own mesh layer
"""Elastic re-meshing for the battery pool.

The paper's war story (§7.4): machines vanish mid-project (re-imaged lab
PCs). At pod scale the equivalent is losing slices. Because job streams are
counter-based (order/worker-independent), shrinking the pool is *pure
re-planning*: completed results stay valid, missing tests are re-packed
onto the surviving workers. No state migrates.
"""
from __future__ import annotations

from typing import Dict, Sequence

from repro.core.scheduler import Plan, replan
from repro.core.stitch import missing


def shrink_and_replan(results: Dict[int, tuple], n_tests: int,
                      costs: Sequence[float], surviving_workers: int,
                      mode: str = "lpt") -> Plan:
    """Plan the remaining work for a reduced pool."""
    todo = missing(results, n_tests)
    if not todo:
        return replan([], costs, max(surviving_workers, 1), mode)
    return replan(todo, costs, max(surviving_workers, 1), mode)

# repro: quarantine -- growth-seed sharding/elastic LM utilities; the battery pool has its own mesh layer
"""Logical-axis -> mesh-axis resolution (GSPMD named sharding rules).

Parallelism mapping (see DESIGN.md §5):
  DP    : batch over ('pod', 'data')
  FSDP  : parameter 'embed' dims over 'data' (ZeRO-3; all-gather per scanned
          layer), optimizer state sharded identically
  TP    : 'heads'/'mlp'/'inner'/'vocab' over 'model' (skipped per-dim when the
          dim is not divisible by the axis — e.g. qwen2's 12 heads on a
          16-way axis fall back to replicated heads, MLP stays sharded)
  EP    : 'experts' over 'model'
  SP    : decode caches shard 'kv_heads' over 'model' when divisible, else
          the *sequence* dim (flash-decoding-style split-K across chips)

Every rule is divisibility-guarded so one rule set covers all 10 archs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.models.params import P as ParamP

# logical name -> preferred mesh axis for parameters
PARAM_RULES = {
    "experts": "model",
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "inner": "model",
    "heads_inner": "model",
    "embed": "data",          # FSDP
    "q_lora": None,
    "kv_lora": None,
    "head_dim": None,
    "ssm_heads": "model",
    "layers": None,
    "inner_layers": None,
}


def _axis_size(mesh, name) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


# Experts smaller than this (bytes/leaf) are REPLICATED instead of
# expert-parallel: for fine-grained MoE (granite: 512-wide experts) the EP
# all-to-all moves more bytes than the experts compute — replicating ~200MB
# of expert weights deletes TBs of collective traffic per step
# (EXPERIMENTS.md §Perf iter 3).
EP_MIN_BYTES = 512e6


def resolve_param_spec(p: ParamP, mesh) -> PartitionSpec:
    import numpy as _np
    used = set()
    out = []
    small_experts = ("experts" in p.axes
                     and int(_np.prod(p.shape)) * 4 < EP_MIN_BYTES)
    for dim, ax in zip(p.shape, p.axes):
        cand = PARAM_RULES.get(ax)
        if ax == "experts" and small_experts:
            cand = None
        if ax == "mlp" and small_experts and "experts" in p.axes:
            cand = "model"     # small experts: TP the expert mlp dim instead
        if (cand and cand in mesh.axis_names and cand not in used
                and dim % _axis_size(mesh, cand) == 0):
            out.append(cand)
            used.add(cand)
        else:
            out.append(None)
    return PartitionSpec(*out)


def param_shardings(cfg, mesh):
    """NamedSharding tree parallel to model params."""
    from repro.models.lm import model_spec
    spec = model_spec(cfg)
    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, resolve_param_spec(p, mesh)),
        spec, is_leaf=lambda x: isinstance(x, ParamP))


def batch_axes(mesh, global_batch: int) -> Optional[Tuple[str, ...]]:
    """Mesh axes for the batch dim of activations/inputs."""
    cands = [a for a in ("pod", "data") if a in mesh.axis_names]
    while cands:
        prod = 1
        for a in cands:
            prod *= _axis_size(mesh, a)
        if global_batch % prod == 0:
            return tuple(cands)
        cands = cands[1:]
    return None


def data_sharding(mesh, global_batch: int) -> NamedSharding:
    ax = batch_axes(mesh, global_batch)
    return NamedSharding(mesh, PartitionSpec(ax, None))


# ---------------------------------------------------------------------------
# decode-cache shardings

def _kv_spec(mesh, batch, seq, kv_heads, lead_dims=1):
    """(units…, B, S, K, dh): prefer kv_heads on 'model', else seq (split-K)."""
    msize = _axis_size(mesh, "model")
    b_ax = batch_axes(mesh, batch)
    if kv_heads % msize == 0:
        body = [b_ax, None, "model", None]
    elif seq % msize == 0:
        body = [b_ax, "model", None, None]
    else:
        body = [b_ax, None, None, None]
    return PartitionSpec(*([None] * lead_dims + body))


def _seq_major_spec(mesh, batch, seq, lead_dims=1, trailing=1):
    """(units…, B, S, feat…): shard seq on 'model' (latent caches)."""
    msize = _axis_size(mesh, "model")
    b_ax = batch_axes(mesh, batch)
    seq_ax = "model" if seq % msize == 0 else None
    return PartitionSpec(*([None] * lead_dims + [b_ax, seq_ax]
                           + [None] * trailing))


def _feat_spec(mesh, batch, shape, batch_idx, feat_idx):
    """State tensors: shard one feature dim on 'model' if divisible."""
    msize = _axis_size(mesh, "model")
    b_ax = batch_axes(mesh, batch)
    out = [None] * len(shape)
    out[batch_idx] = b_ax
    if shape[feat_idx] % msize == 0:
        out[feat_idx] = "model"
    return PartitionSpec(*out)


def cache_pspecs(cfg, batch: int, max_seq: int, mesh):
    """PartitionSpec tree mirroring ``repro.models.lm.init_cache``."""
    fam = cfg.family
    kh = cfg.n_kv_heads
    out = {"pos": PartitionSpec()}
    if fam in ("dense", "vlm"):
        from repro.models.lm import _unit_structure
        _, pat = _unit_structure(cfg)
        kinds = pat if len(pat) > 1 else ("blk",)
        kv = {"k": _kv_spec(mesh, batch, max_seq, kh),
              "v": _kv_spec(mesh, batch, max_seq, kh)}
        out["units"] = {k: dict(kv) for k in kinds}
    elif fam == "moe":
        m = cfg.moe
        if cfg.mla is not None:
            unit = {"ckv": _seq_major_spec(mesh, batch, max_seq),
                    "kr": _seq_major_spec(mesh, batch, max_seq)}
            if m.first_dense_layers:
                out["head"] = dict(unit)
            out["units"] = dict(unit)
        else:
            out["units"] = {"k": _kv_spec(mesh, batch, max_seq, kh),
                            "v": _kv_spec(mesh, batch, max_seq, kh)}
    elif fam == "audio":
        out["units"] = {"k": _kv_spec(mesh, batch, max_seq, kh),
                        "v": _kv_spec(mesh, batch, max_seq, kh)}
        out["cross"] = {"k": _kv_spec(mesh, batch, cfg.encoder_seq, kh),
                        "v": _kv_spec(mesh, batch, cfg.encoder_seq, kh)}
    elif fam == "ssm":
        from repro.models.xlstm import _mdims
        x = cfg.xlstm
        inner, heads, mdh = _mdims(cfg)
        ns, nm = cfg.n_layers // x.slstm_every, x.slstm_every - 1
        d = cfg.d_model
        out["mlstm"] = {
            "c": _feat_spec(mesh, batch, (ns, nm, batch, heads, mdh, mdh), 2, 4),
            "n": _feat_spec(mesh, batch, (ns, nm, batch, heads, mdh), 2, 4),
            "m": _feat_spec(mesh, batch, (ns, nm, batch, heads), 2, 3),
            "conv": _feat_spec(mesh, batch,
                               (ns, nm, batch, x.conv_width - 1, inner), 2, 4)}
        out["slstm"] = {
            k: _feat_spec(mesh, batch, (ns, batch, d), 1, 2)
            for k in ("c", "n", "h", "m")}
        out["slstm"]["conv"] = _feat_spec(
            mesh, batch, (ns, batch, x.conv_width - 1, d), 1, 3)
    elif fam == "hybrid":
        from repro.models.ssm import _dims
        s = cfg.ssm
        d_inner, n_heads, conv_dim = _dims(cfg)
        k = cfg.shared_attn_every
        n_full = cfg.n_layers // k
        tail = cfg.n_layers - n_full * k
        out["attn"] = {"k": _kv_spec(mesh, batch, max_seq, kh),
                       "v": _kv_spec(mesh, batch, max_seq, kh)}
        out["mamba"] = {
            "conv": _feat_spec(mesh, batch,
                               (n_full, k, batch, s.d_conv - 1, conv_dim), 2, 4),
            "ssm": _feat_spec(mesh, batch,
                              (n_full, k, batch, n_heads, s.head_dim,
                               s.d_state), 2, 3)}
        if tail:
            out["tail"] = {
                "conv": _feat_spec(mesh, batch,
                                   (tail, batch, s.d_conv - 1, conv_dim), 1, 3),
                "ssm": _feat_spec(mesh, batch,
                                  (tail, batch, n_heads, s.head_dim,
                                   s.d_state), 1, 2)}
    return out


def cache_shardings(cfg, batch, max_seq, mesh):
    return jax.tree_util.tree_map(
        lambda ps: NamedSharding(mesh, ps),
        cache_pspecs(cfg, batch, max_seq, mesh),
        is_leaf=lambda x: isinstance(x, PartitionSpec))

# repro: quarantine -- growth-seed sharding/elastic LM utilities; the battery pool has its own mesh layer
"""Int8 gradient compression for cross-pod DP all-reduce.

At 2+ pods the DP gradient reduction crosses the (slow) inter-pod links;
per-tensor-scaled int8 quantization cuts that traffic 4x vs fp32 (2x vs
bf16) at <1e-2 relative error on AdamW-scale gradients. Used inside a
``shard_map`` over the 'pod' axis (see ``cross_pod_mean``); within-pod
reductions stay full precision.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map


def quantize_int8(x):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str):
    """Quantize -> psum(int32) -> dequantize with psum'd scale.

    Scales differ per pod, so the sum uses the max scale (conservative,
    error still bounded by 1/127 of the largest-|g| pod)."""
    q, scale = quantize_int8(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    q32 = jnp.round(dequantize_int8(q, scale) / scale_max
                    ).astype(jnp.int32)
    total = jax.lax.psum(q32, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale_max / n


def cross_pod_mean(grads, mesh):
    """Mean of a grad pytree across the 'pod' axis with int8 transport.

    Grads enter replicated within pods (already DP-reduced inside the pod)
    and sharded however they like on data/model; shard_map runs per pod."""
    if "pod" not in mesh.axis_names:
        return grads

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=jax.tree_util.tree_map(lambda _: P("pod"), grads),
        out_specs=jax.tree_util.tree_map(lambda _: P("pod"), grads),
        check_vma=False)
    def reduce_fn(g):
        return jax.tree_util.tree_map(
            lambda t: compressed_psum(t, "pod"), g)

    return reduce_fn(grads)

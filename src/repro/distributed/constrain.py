# repro: quarantine -- growth-seed sharding/elastic LM utilities; the battery pool has its own mesh layer
"""Activation sharding constraints (mesh-context aware, no-op without mesh).

GSPMD sharding propagation can drop the batch sharding inside while-loop
bodies (scan-over-layers backward, blocked-attention inner scans) and fall
back to fully-replicated intermediates — catastrophic at global-batch scale.
Pinning activations at module boundaries keeps propagation honest; this is
the same discipline MaxText applies via logical axis constraints.

``constrain(x, *logical)`` maps logical names -> mesh axes with divisibility
guards, so a single call site works on every mesh (or none: unit tests run
without a mesh and the helper is a no-op).
"""
from __future__ import annotations

import jax
from jax.interpreters import pxla
from jax.sharding import NamedSharding, PartitionSpec


def _current_mesh():
    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty:
        return None
    return mesh


import os

# Sequence-parallel residual stream (Megatron SP): sharding the 'seq' dim of
# block inputs/outputs over 'model' turns the row-parallel TP all-reduces
# into reduce-scatter + all-gather pairs (~half the bytes) and shrinks
# replicated activations TP-fold. Measured win on unshardable-head archs
# (qwen2/whisper) — see EXPERIMENTS.md §Perf iter 2. Off by default; the
# dry-run enables it per-arch.
SEQ_PARALLEL = os.environ.get("REPRO_SEQ_PARALLEL", "0") == "1"

_RULES = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "inner": ("model",),
    "experts": ("model",),
    "vocab": ("model",),
    "seq": (),
    "seq_sp": ("model",),      # only used when SEQ_PARALLEL
    None: (),
}


def seq_axis():
    return "seq_sp" if SEQ_PARALLEL else "seq"


def constrain(x, *logical):
    """Apply a sharding constraint by logical dim names; no-op without mesh."""
    mesh = _current_mesh()
    if mesh is None or x.ndim != len(logical):
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used = set()
    spec = []
    for dim, name in zip(x.shape, logical):
        axes = []
        for ax in _RULES.get(name, ()):
            if ax in sizes and ax not in used:
                prod = sizes[ax]
                for a in axes:
                    prod *= sizes[a]
                if dim % prod == 0:
                    axes.append(ax)
                    used.add(ax)
        if not axes:
            spec.append(None)
        elif len(axes) == 1:
            spec.append(axes[0])
        else:
            spec.append(tuple(axes))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec)))

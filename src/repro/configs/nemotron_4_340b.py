# repro: quarantine -- growth-seed LM model configs; nothing in the battery system reads them
"""nemotron-4-340b [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000; squared-ReLU
(non-gated) MLP. Memory preset: bf16 params + bf16 Adam moments
(8-bit-Adam-class footprint) — see DESIGN.md memory notes.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    act="relu2",
    gated_mlp=False,
    rope=True,
    rope_theta=10000.0,
    param_dtype="bfloat16",
    adam_dtype="bfloat16",
    remat_policy="full",
    scan_group=8,                  # nested remat: 12 groups of 8 layers
    train_accum=16,
)


def reduced():
    import dataclasses
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=256, vocab_size=256,
                               scan_group=0, param_dtype="float32",
                               adam_dtype="float32")

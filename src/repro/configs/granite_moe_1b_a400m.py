# repro: quarantine -- growth-seed LM model configs; nothing in the battery system reads them
"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) vocab=49155, MoE 32 experts top-8,
expert d_ff=512 (SwiGLU experts).
"""
from repro.common.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,                      # per-expert d_ff
    vocab_size=49155,
    act="silu",
    gated_mlp=True,
    rope=True,
    rope_theta=10000.0,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
)


def reduced():
    """Smoke-test scale config of the same family."""
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab_size=256, moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32),
    )

# repro: quarantine -- growth-seed LM model configs; nothing in the battery system reads them
"""deepseek-v2-236b [arXiv:2405.04434].

60L d_model=5120 128H, MLA (kv_lora=512, q_lora=1536, rope dim 64),
2 shared + 160 routed experts top-6, expert d_ff=1536, first layer dense
(d_ff=12288), vocab=102400.
"""
from repro.common.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,                # MLA: per-head KV derived from shared latent
    d_ff=1536,                     # per-expert d_ff
    vocab_size=102400,
    act="silu",
    gated_mlp=True,
    rope=True,
    rope_theta=10000.0,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                  n_shared=2, d_ff_shared=1536,
                  first_dense_layers=1, d_ff_dense=12288),
    remat_policy="full",
    train_accum=16,
)


def reduced():
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
        vocab_size=256,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                      d_ff_shared=32, first_dense_layers=1, d_ff_dense=64),
    )

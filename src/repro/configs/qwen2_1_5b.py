# repro: quarantine -- growth-seed LM model configs; nothing in the battery system reads them
"""qwen2-1.5b [arXiv:2407.10671]. 28L d1536 12H (GQA kv=2) d_ff=8960 vocab=151936, QKV bias."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    act="silu",
    gated_mlp=True,
    qkv_bias=True,
    rope=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    train_accum=4,                 # 12 heads unshardable on TP=16 -> shrink
)


def reduced():
    import dataclasses
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=128, vocab_size=256)

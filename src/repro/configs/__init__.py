# repro: quarantine -- growth-seed LM model configs; nothing in the battery system reads them
"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``."""
from __future__ import annotations

import importlib

_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "glm4-9b": "glm4_9b",
    "gemma2-27b": "gemma2_27b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen2-1.5b": "qwen2_1_5b",
    "chameleon-34b": "chameleon_34b",
    "whisper-small": "whisper_small",
    "xlstm-1.3b": "xlstm_1_3b",
    "zamba2-1.2b": "zamba2_1_2b",
}

ARCH_IDS = tuple(_MODULES)


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str):
    return _mod(arch_id).CONFIG


def get_reduced(arch_id: str):
    return _mod(arch_id).reduced()

# repro: quarantine -- growth-seed LM model configs; nothing in the battery system reads them
"""xlstm-1.3b [arXiv:2405.04517].

48 blocks d_model=2048, 4 heads, mLSTM:sLSTM = 7:1 (xLSTM[7:1]), no separate
FFN (d_ff=0; blocks carry their own projections), vocab=50304.
"""
from repro.common.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    arch_id="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    rope=False,
    xlstm=XLSTMConfig(slstm_every=8, proj_factor_m=2.0,
                      proj_factor_s=4.0 / 3.0, conv_width=4, chunk=128),
    train_accum=4,
)


def reduced():
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        vocab_size=256,
        xlstm=XLSTMConfig(slstm_every=2, conv_width=4, chunk=16),
    )

# repro: quarantine -- growth-seed LM model configs; nothing in the battery system reads them
"""zamba2-1.2b [arXiv:2411.15242].

38 Mamba-2 layers d_model=2048 (ssm_state=64) + ONE shared attention(+MLP)
block (32H MHA, d_ff=8192) applied every 6 ssm layers with shared weights,
vocab=32000.
"""
from repro.common.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,                     # shared block MLP
    vocab_size=32000,
    act="gelu",
    gated_mlp=True,
    rope=True,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    shared_attn_every=6,
    train_accum=4,
)


def reduced():
    import dataclasses
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, shared_attn_every=2,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
    )

# repro: quarantine -- growth-seed LM model configs; nothing in the battery system reads them
"""chameleon-34b [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536; early-fusion VQ image
tokens share the text vocab (frontend stub: inputs are token ids over the
fused vocab). QK-norm for stability (per the paper).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    act="silu",
    gated_mlp=True,
    qk_norm=True,
    rope=True,
    rope_theta=10000.0,
    frontend="fused",
    scan_group=8,
    train_accum=8,
)


def reduced():
    import dataclasses
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=128, vocab_size=256,
                               scan_group=0)

# repro: quarantine -- growth-seed LM model configs; nothing in the battery system reads them
"""whisper-small [arXiv:2212.04356].

Enc-dec, 12+12L d_model=768 12H (MHA kv=12) d_ff=3072 (plain GELU)
vocab=51865. Conv frontend is a STUB: ``input_specs`` supplies precomputed
frame embeddings (B, 1500, 768); decoder shapes follow the assigned cells.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="audio",
    n_layers=12,                   # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    act="gelu_plain",
    gated_mlp=False,
    rope=False,                    # whisper: learned/sinusoidal absolute pos
    is_encoder_decoder=True,
    n_encoder_layers=12,
    encoder_seq=1500,
    frontend="frames",
    norm_eps=1e-5,
)


def reduced():
    import dataclasses
    return dataclasses.replace(CONFIG, n_layers=2, n_encoder_layers=2,
                               d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                               vocab_size=256, encoder_seq=32)

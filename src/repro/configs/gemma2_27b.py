# repro: quarantine -- growth-seed LM model configs; nothing in the battery system reads them
"""gemma2-27b [arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16, head_dim=128) d_ff=36864 (GeGLU)
vocab=256000; alternating local(4096)/global attention; attn softcap 50,
final logit softcap 30; query scale 1/sqrt(d_model/n_heads)=1/sqrt(144).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    act="gelu",
    gated_mlp=True,
    rope=True,
    rope_theta=10000.0,
    tie_embeddings=True,
    attn_pattern=("local", "global"),
    local_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=(4608 / 32) ** -0.5,
    post_block_norm=True,
    train_accum=4,
)


def reduced():
    import dataclasses
    return dataclasses.replace(CONFIG, n_layers=4, d_model=64, n_heads=4,
                               n_kv_heads=2, head_dim=16, d_ff=128,
                               vocab_size=256, local_window=16,
                               query_scale=(64 / 4) ** -0.5)

# repro: quarantine -- growth-seed LM model configs; nothing in the battery system reads them
"""glm4-9b [hf:THUDM/glm-4-9b]. 40L d4096 32H (GQA kv=2) d_ff=13696 vocab=151552."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    act="silu",
    gated_mlp=True,
    qkv_bias=True,                 # GLM-4 uses QKV bias
    rope=True,
    rope_theta=10000.0,
    train_accum=8,
)


def reduced():
    import dataclasses
    return dataclasses.replace(CONFIG, n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=128, vocab_size=256)

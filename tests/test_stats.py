"""Statistical validity of the battery (calibration + canaries) and
property tests for the RNG substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.battery import build_battery
from repro.core.pool import run_sequential
from repro.rng import generators as G
from repro.stats import special
from repro.stats.tests import KERNELS

SCALE = 0.125


@pytest.fixture(scope="module")
def entries():
    return build_battery("smallcrush", SCALE)


def _suspects(ps):
    ps = np.asarray(ps)
    return int(((ps < 1e-4) | (ps > 1 - 1e-4)).sum())


@pytest.mark.slow
@pytest.mark.parametrize("gen", ["splitmix64", "threefry", "pcg32",
                                 "xorshift64s", "mwc", "msweyl", "lcg64"])
def test_good_generators_pass(entries, gen):
    _, ps = run_sequential(entries, 9, G.GEN_IDS[gen])
    assert _suspects(ps) == 0, np.asarray(ps)


@pytest.mark.slow
@pytest.mark.parametrize("gen,min_fail", [("randu", 2), ("minstd", 1)])
def test_bad_generators_fail(entries, gen, min_fail):
    _, ps = run_sequential(entries, 9, G.GEN_IDS[gen])
    assert _suspects(ps) >= min_fail


@pytest.mark.slow
def test_pvalues_roughly_uniform(entries):
    """Meta-test: pooled good-generator p-values are not clustered."""
    allp = []
    for seed in range(6):
        _, ps = run_sequential(entries, seed, G.GEN_IDS["splitmix64"])
        allp.extend(np.asarray(ps).tolist())
    allp = np.array(allp)
    assert 0.25 < allp.mean() < 0.75
    assert (allp < 0.5).sum() > len(allp) * 0.2


# ------------------------------------------------------------- RNG substrate

@given(seed=st.integers(0, 1000), stream=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_streams_deterministic_and_distinct(seed, stream):
    with G.x64():
        a = np.asarray(G.splitmix64_block(seed, stream, 64))
        b = np.asarray(G.splitmix64_block(seed, stream, 64))
        c = np.asarray(G.splitmix64_block(seed, stream + 1, 64))
    assert (a == b).all()
    assert (a != c).any()


def test_counter_offset_continuation():
    """block(n=2k) == block(n=k) ++ block(n=k, offset=k) — what makes
    sequential-reuse mode and over-decomposition exact."""
    with G.x64():
        full = np.asarray(G.splitmix64_block(5, 1, 128))
        a = np.asarray(G.splitmix64_block(5, 1, 64))
        b = np.asarray(G.splitmix64_block(5, 1, 64, offset=64))
    assert (full == np.concatenate([a, b])).all()


@pytest.mark.parametrize("gen", G.COUNTER_BASED)
@given(seed=st.integers(0, 2 ** 16), stream=st.integers(0, 2 ** 16),
       k=st.integers(1, 96))
@settings(max_examples=10, deadline=None)
def test_counter_offset_continuation_all(gen, seed, stream, k):
    """The continuation property must hold for EVERY counter-based
    generator at arbitrary split points, not just splitmix64 at 64."""
    fn = G.GENERATORS[gen]
    with G.x64():
        full = np.asarray(fn(seed, stream, 2 * k))
        a = np.asarray(fn(seed, stream, k))
        b = np.asarray(fn(seed, stream, k, offset=k))
    assert (full == np.concatenate([a, b])).all(), (gen, seed, stream, k)


@pytest.mark.parametrize("gen", G.COUNTER_BASED)
@given(seed=st.integers(0, 1000),
       streams=st.sets(st.integers(0, 10000), min_size=2, max_size=5))
@settings(max_examples=10, deadline=None)
def test_streams_pairwise_disjoint_first_k(gen, seed, streams):
    """Distinct streams of the same generator must produce pairwise
    distinct first-k word blocks — sub-jobs drawing 'fresh' sub-streams
    genuinely get fresh bits (pool.stream_table's contract)."""
    k = 64
    fn = G.GENERATORS[gen]
    with G.x64():
        blocks = {s: np.asarray(fn(seed, s, k)) for s in streams}
    items = sorted(blocks)
    for i, s1 in enumerate(items):
        for s2 in items[i + 1:]:
            assert (blocks[s1] != blocks[s2]).any(), (gen, seed, s1, s2)


def test_lcg_jump_matches_sequential():
    """O(log n) jump-ahead must equal stepping the recurrence."""
    with G.x64():
        jumped = np.asarray(G.lcg64_block(3, 2, 16), np.uint64)
        s = np.uint64(0)
        import numpy as _np
        with _np.errstate(over="ignore"):
            s = (_np.uint64(3) * _np.uint64(G.LCG_A * 2094213091 % 2**64))
        # recompute directly: state_i for i=0.. via numpy
        st = np.asarray(G._mix_seed(3, 2)).astype(np.uint64)
        out = []
        x = int(st)
        for i in range(16):
            out.append((x >> 32) & 0xFFFFFFFF)
            x = (G.LCG_A * x + G.LCG_C) % 2 ** 64
        assert (jumped == np.array(out, np.uint64).astype(np.uint32)).all()


def test_to_unit_range():
    with G.x64():
        bits = G.splitmix64_block(0, 0, 4096)
    u = np.asarray(G.to_unit(bits))
    assert (u >= 0).all() and (u < 1).all()
    assert 0.45 < u.mean() < 0.55


# ------------------------------------------------------------ special funcs

def test_chi2_sf_sanity():
    assert float(special.chi2_sf(jnp.float32(0.0), 5.0)) == pytest.approx(1.0)
    # median of chi2_k is ~ k(1-2/9k)^3
    assert float(special.chi2_sf(jnp.float32(4.35), 5.0)) == pytest.approx(
        0.5, abs=0.02)


def test_kernels_uniform_signature(entries):
    """Every kernel returns finite (stat, p) on random bits — the contract
    the pool's lax.switch dispatch relies on."""
    with G.x64():
        bits = G.splitmix64_block(1, 1, 262144)   # covers kernel defaults
    for name, fn in KERNELS.items():
        stat, p = fn(bits)
        assert jnp.isfinite(stat), name
        assert 0.0 <= float(p) <= 1.0, (name, float(p))

"""Per-kernel allclose vs pure-jnp oracles (interpret mode), with
hypothesis shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gf2_rank.ops import rank32
from repro.kernels.gf2_rank.ref import gf2_rank_ref
from repro.kernels.histogram.ops import bincount
from repro.kernels.histogram.ref import histogram_ref


# ---------------------------------------------------------------------- rank

@given(m=st.integers(1, 700), seed=st.integers(0, 100))
@settings(max_examples=12, deadline=None)
def test_gf2_rank_matches_ref(m, seed):
    mats = jax.random.bits(jax.random.PRNGKey(seed), (m, 32), jnp.uint32)
    assert (rank32(mats) == gf2_rank_ref(mats)).all()


def test_gf2_rank_known_cases():
    eye = (jnp.uint32(1) << (31 - jnp.arange(32, dtype=jnp.uint32)))
    assert int(rank32(eye[None])[0]) == 32
    assert int(rank32(jnp.zeros((1, 32), jnp.uint32))[0]) == 0
    assert int(rank32(jnp.full((1, 32), 1, jnp.uint32))[0]) == 1
    # duplicated rows halve the rank
    half = jnp.concatenate([eye[:16], eye[:16]])[None]
    assert int(rank32(half.reshape(1, 32))[0]) == 16


# ----------------------------------------------------------------- histogram

@given(n=st.integers(1, 6000), k=st.sampled_from([8, 37, 64, 257]),
       seed=st.integers(0, 50))
@settings(max_examples=12, deadline=None)
def test_histogram_matches_ref(n, k, seed):
    idx = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, k)
    assert (bincount(idx, k) == histogram_ref(idx, k)).all()


def test_histogram_total():
    idx = jnp.zeros((4096,), jnp.int32)
    out = bincount(idx, 4)
    assert float(out[0]) == 4096 and float(out[1:].sum()) == 0


# ----------------------------------------------------------- flash attention

@pytest.mark.parametrize("b,s,h,kh,dh,cap,dtype", [
    (2, 256, 4, 2, 64, 0.0, jnp.float32),
    (1, 384, 2, 2, 128, 50.0, jnp.float32),
    (1, 128, 8, 1, 64, 0.0, jnp.float32),      # MQA
    (2, 256, 4, 4, 64, 0.0, jnp.bfloat16),
])
def test_flash_attention_matches_ref(b, s, h, kh, dh, cap, dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, dh), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kh, dh), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kh, dh), dtype)
    o = mha(q, k, v, scale=dh ** -0.5, softcap=cap)
    rep = h // kh
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kr = jnp.repeat(k, rep, 2).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    vr = jnp.repeat(v, rep, 2).transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    o_ref = attention_ref(qr, kr, vr, scale=dh ** -0.5, softcap=cap)
    o_ref = o_ref.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol)


def test_flash_vs_model_blocked_path():
    """Kernel agrees with the model's XLA blocked-attention twin."""
    from repro.models import attention as A
    b, s, h, dh = 1, 2048, 4, 64
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (b, s, h, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, dh))
    pos = jnp.arange(s)
    xla = A.sdpa(q, k, v, pos, pos, "causal", 0, dh ** -0.5, 0.0)
    pallas = mha(q, k, v, scale=dh ** -0.5)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pallas),
                               atol=3e-5)

"""Training loop + checkpoint/restart + sharding-rule tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import io as ckpt_io
from repro.configs import get_reduced
from repro.distributed.sharding import resolve_param_spec
from repro.launch.train import train
from repro.models.params import P
from repro.train.optim import OptConfig, adamw_update, init_opt_state, lr_at


def test_loss_decreases():
    _, losses = train("qwen2-1.5b", steps=30, global_batch=4, seq_len=64,
                      log_every=0)
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_checkpoint_restart_exact(tmp_path):
    ck = str(tmp_path / "t.ck")
    state_a, _ = train("qwen2-1.5b", steps=10, global_batch=2, seq_len=32,
                       ckpt_path=ck, ckpt_every=5, log_every=0)
    # restart from step 10 checkpoint and continue to 14
    state_b, _ = train("qwen2-1.5b", steps=14, global_batch=2, seq_len=32,
                       ckpt_path=ck, ckpt_every=100, log_every=0)
    # fresh run straight to 14 must match bitwise (restart-exactness)
    state_c, _ = train("qwen2-1.5b", steps=14, global_batch=2, seq_len=32,
                       log_every=0)
    for b, c in zip(jax.tree.leaves(state_b["params"]),
                    jax.tree.leaves(state_c["params"])):
        np.testing.assert_array_equal(np.asarray(b), np.asarray(c))


def test_adamw_moves_params():
    cfg = get_reduced("qwen2-1.5b")
    from repro.models import lm
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    grads = jax.tree.map(jnp.ones_like, params)
    new_p, new_opt, m = adamw_update(params, grads, opt, OptConfig())
    assert int(new_opt["step"]) == 1
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree.leaves(params),
                             jax.tree.leaves(new_p))]
    assert max(diffs) > 0


def test_lr_schedule_shape():
    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(jnp.int32(0), oc)) < float(lr_at(jnp.int32(9), oc))
    assert float(lr_at(jnp.int32(99), oc)) < float(lr_at(jnp.int32(50), oc))
    assert float(lr_at(jnp.int32(99), oc)) >= oc.lr * oc.min_lr_frac * 0.9


def test_ckpt_roundtrip(tmp_path):
    tree = {"a": np.arange(10, dtype=np.float32),
            "b": {"c": np.ones((3, 4), np.int32)}}
    path = str(tmp_path / "x.ck")
    ckpt_io.save(path, tree)
    back = ckpt_io.load_into(path, tree)
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["b"]["c"], tree["b"]["c"])


# --------------------------------------------------------------- sharding

class _FakeMesh:
    axis_names = ("data", "model")

    class devices:  # noqa: N801
        shape = (16, 16)


def test_param_rules_divisibility_fallback():
    mesh = _FakeMesh()
    # 12 heads don't divide 16 -> replicated; mlp 8960 does -> sharded
    spec = resolve_param_spec(P((1536, 12, 128),
                                ("embed", "heads", "head_dim")), mesh)
    assert spec == jax.sharding.PartitionSpec("data", None, None)
    spec = resolve_param_spec(P((1536, 8960), ("embed", "mlp")), mesh)
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # small experts (67MB) are replicated, expert-MLP dim TP'd instead
    # (EXPERIMENTS.md §Perf iter 3)
    spec = resolve_param_spec(P((32, 1024, 512),
                                ("experts", "embed", "mlp")), mesh)
    assert spec == jax.sharding.PartitionSpec(None, "data", "model")
    # big experts (15GB: deepseek) keep true EP; mlp falls back to None
    spec = resolve_param_spec(P((160, 5120, 1536),
                                ("experts", "embed", "mlp")), mesh)
    assert spec == jax.sharding.PartitionSpec("model", "data", None)


def test_constrain_noop_without_mesh():
    from repro.distributed.constrain import constrain
    x = jnp.ones((4, 8))
    assert constrain(x, "batch", None) is x


# ------------------------------------------------------ compression/elastic

def test_int8_compression_roundtrip():
    from repro.distributed.compress import dequantize_int8, quantize_int8
    x = jnp.array(np.random.default_rng(0).normal(0, 0.01, (1000,)),
                  jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.51


def test_elastic_shrink_replan():
    from repro.distributed.elastic import shrink_and_replan
    results = {i: (1.0, 0.5) for i in range(50) if i % 7}
    plan = shrink_and_replan(results, 50, [1.0] * 50, surviving_workers=3)
    covered = sorted(int(i) for i in plan.assignment.ravel() if i >= 0)
    assert covered == [i for i in range(50) if i % 7 == 0]

"""The static-analysis suite (DESIGN.md §9): fixture corpus, repo
self-check, and the cache-key mutation test.

Three layers, mirroring how the analyzer is meant to be trusted:

  1. every bad fixture in tests/analysis_fixtures/ fires EXACTLY its
     intended rule code, and every good fixture fires nothing — the
     rules have both the sensitivity and the specificity they claim;
  2. the real repo tree is clean modulo the (empty) baseline — the CI
     gate's exit-0 is reproduced in-process;
  3. mutation tests: re-introducing the PR 4 resolved-backend bug
     (dropping ``backend`` from the session trace-cache key) makes
     RPA201 fire, so that bug class is mechanically non-reintroducible.

The analysis package is stdlib-only, so none of this imports jax.
"""
import os
import re

import pytest

from repro.analysis import Baseline, Project, run_analysis
from repro.analysis.registry import rules

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "analysis_fixtures")

# single-file fixtures are mounted here: a src path (so module-name
# mapping works) outside every known-traced module prefix
MOUNT = "src/repro/fixtures/snippet.py"


def _fixture_files():
    return sorted(f for f in os.listdir(FIXTURES) if f.endswith(".py"))


def _fixture_trees():
    return sorted(d for d in os.listdir(FIXTURES)
                  if os.path.isdir(os.path.join(FIXTURES, d)))


def _project_for(name):
    """Mount a fixture as a virtual Project (same path the CLI runs)."""
    full = os.path.join(FIXTURES, name)
    if os.path.isdir(full):
        files = {}
        for dirpath, _dirs, fnames in os.walk(full):
            for fname in fnames:
                fpath = os.path.join(dirpath, fname)
                rel = os.path.relpath(fpath, full).replace(os.sep, "/")
                with open(fpath, encoding="utf-8") as f:
                    files[rel] = f.read()
        return Project(files)
    with open(full, encoding="utf-8") as f:
        return Project({MOUNT: f.read()})


def _codes(name):
    result = run_analysis(_project_for(name))
    assert not result.syntax_errors, f"{name} does not parse"
    return sorted({f.code for f in result.findings})


def _intended(name):
    m = re.match(r"(RPA\d{3})_", name)
    assert m, f"fixture {name!r} must be named RPAnnn_*"
    return m.group(1)


BAD = [n for n in _fixture_files() + _fixture_trees() if "_bad" in n]
GOOD = [n for n in _fixture_files() + _fixture_trees() if "_good" in n]


def test_corpus_shape():
    """ISSUE 6 acceptance: >= 10 bad fixtures across >= 4 families."""
    assert len(BAD) >= 10, BAD
    families = {_intended(n)[:4] for n in BAD}
    assert len(families) >= 4, families
    assert BAD and GOOD
    # every fixture name references a registered rule code
    known = {r.code for r in rules()}
    for n in BAD + GOOD:
        assert _intended(n) in known, n


@pytest.mark.parametrize("name", [n for n in _fixture_files()
                                  + _fixture_trees() if "_bad" in n])
def test_bad_fixture_fires_exactly_its_code(name):
    assert _codes(name) == [_intended(name)], name


@pytest.mark.parametrize("name", [n for n in _fixture_files()
                                  + _fixture_trees() if "_good" in n])
def test_good_fixture_is_clean(name):
    assert _codes(name) == [], name


def test_noqa_fixture_is_suppressed_not_silent():
    """The RPA102 noqa fixture would fire without its suppression."""
    result = run_analysis(_project_for("RPA102_noqa_good.py"))
    assert [f.code for f in result.suppressed] == ["RPA102"]
    src = _project_for("RPA102_noqa_good.py").source(MOUNT)
    stripped = src.replace("  # repro: noqa RPA102", "")
    bare = run_analysis(Project({MOUNT: stripped}))
    assert [f.code for f in bare.findings] == ["RPA102"]


# ---------------------------------------------------------------------------
# repo self-check: the tree the CI gate sees is clean modulo the baseline

def test_repo_tree_is_clean_modulo_baseline():
    project = Project.from_tree(REPO)
    baseline = Baseline.load(
        os.path.join(REPO, ".repro-analysis-baseline.json"))
    result = run_analysis(project, baseline)
    assert result.files_scanned > 50
    assert not result.syntax_errors, result.syntax_errors
    assert result.findings == [], "\n".join(
        str(f) for f in result.findings)
    # strict gate: the shipped baseline is empty and must stay that way
    assert result.clean(strict=True), result.stale_baseline


def test_repo_suppressions_are_the_known_oracles():
    """Inline suppressions on the real tree are enumerated here, so a
    new one is a conscious decision with a test diff."""
    project = Project.from_tree(REPO)
    result = run_analysis(project)
    suppressed = sorted((f.code, f.path) for f in result.suppressed)
    assert suppressed == [
        ("RPA501", "src/repro/kernels/gf2_rank/ref.py"),
        ("RPA501", "src/repro/kernels/histogram/ref.py"),
    ]


# ---------------------------------------------------------------------------
# mutation tests: the analyzer catches the bug classes it was built for

def _api_source():
    with open(os.path.join(REPO, "src/repro/core/api.py"),
              encoding="utf-8") as f:
        return f.read()


def _mutated_project(old, new):
    src = _api_source()
    assert old in src, "mutation anchor drifted — update this test"
    project = Project.from_tree(REPO)
    files = dict(project.files)
    files["src/repro/core/api.py"] = src.replace(old, new)
    return Project(files)


def test_mutation_dropping_backend_from_cache_key_fires_rpa201():
    """ISSUE 6 acceptance: deleting ``backend`` from the session
    trace-cache key re-introduces the PR 4 bug — RPA201 must fire."""
    project = _mutated_project(
        "policy.signature(), kernel_backends.resolve(spec.backend))",
        "policy.signature())")
    result = run_analysis(project, codes=["RPA201"])
    hits = [f for f in result.findings if f.code == "RPA201"
            and f.path == "src/repro/core/api.py"]
    assert hits, "RPA201 did not catch the dropped backend key field"
    assert any("backend" in f.message for f in hits)


def test_mutation_unclassified_runspec_field_fires_rpa202():
    """Removing a runtime-arg classification resurfaces RPA202."""
    project = _mutated_project("alpha: float = 0.01  # repro: runtime-arg",
                               "alpha: float = 0.01")
    result = run_analysis(project, codes=["RPA202"])
    assert any(f.code == "RPA202" and "alpha" in f.message
               for f in result.findings)


def test_mutation_unquarantined_seed_module_fires_rpa501():
    """Stripping a quarantine annotation resurfaces RPA501."""
    project = Project.from_tree(REPO)
    files = dict(project.files)
    path = "src/repro/models/lm.py"
    head, _, rest = files[path].partition("\n")
    assert "repro: quarantine" in head
    files[path] = rest
    result = run_analysis(Project(files), codes=["RPA501"])
    assert any(f.path == path for f in result.findings)


def test_baseline_grandfathers_then_goes_stale():
    """Baseline lifecycle on a virtual project: a baselined finding is
    not actionable; fixing it strands a stale entry that --strict
    rejects (the baseline may only shrink)."""
    with open(os.path.join(FIXTURES, "RPA401_bad.py"),
              encoding="utf-8") as f:
        bad = f.read()
    project = Project({MOUNT: bad})
    first = run_analysis(project)
    assert len(first.findings) == 1
    baseline = Baseline({f.key() for f in first.findings})
    grandfathered = run_analysis(project, baseline)
    assert grandfathered.findings == []
    assert len(grandfathered.baselined) == 1
    assert grandfathered.clean(strict=True)
    # "fix" the finding: the stale entry now fails strict mode only
    with open(os.path.join(FIXTURES, "RPA401_good.py"),
              encoding="utf-8") as f:
        good = f.read()
    fixed = run_analysis(Project({MOUNT: good}), baseline)
    assert fixed.findings == []
    assert len(fixed.stale_baseline) == 1
    assert fixed.clean(strict=False)
    assert not fixed.clean(strict=True)

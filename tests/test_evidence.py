"""Calibration + property suite for the e-value verdict engine
(core/evidence.py, DESIGN.md §13).

Four layers, cheapest first:

  calibrator math      the κp^(κ-1) family and the mixture calibrator
                       are exactly what the paper trail promises: unit
                       mean under the null (numerically integrated),
                       closed form == numeric κ-integration, correct
                       limits at both ends of [0, 1].
  engine semantics     ``evidence_verdict`` decision logic: Ville
                       crossing, completion PASS, the borderline band,
                       validation, trajectory bookkeeping.
  calibration          the anytime false-FAIL rate on synthetic null
                       batteries stays within the binomial CI of alpha
                       (the PR 2 harness machinery: Wilson intervals),
                       including under adversarial interim looks; the
                       power gate has randu FAIL crush within 12 rounds.
  end to end           real batteries/campaigns/serve under
                       ``verdict_engine="evalue"``: wealth trajectories,
                       checkpoint v5 wealth leaves, engine-mismatch
                       refusal, borderline continuation, cache engine
                       isolation.

Property tests use ``hypothesis`` when available and the deterministic
conftest shim otherwise.
"""
import math
import os

import numpy as np
import pytest

_trapz = getattr(np, "trapezoid", np.trapz)

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.ckpt import io as ckpt_io
from repro.core import evidence, stitch
from repro.core.api import (CampaignSpec, Checkpoint, PoolSession,
                            RunSpec)
from repro.core.campaign import Campaign
from repro.core.evidence import (CALIBRATORS, EvidenceVerdict,
                                 VerdictEngineMismatch, combine_log_wealth,
                                 evidence_verdict, kappa_calibrator,
                                 log_evalue, log_kappa_evalue,
                                 log_mixture_evalue, mixture_calibrator,
                                 two_sided_p, wealth_from_log)
from repro.core.stitch import FAIL, PASS, UNDECIDED

SCALE = 0.0625
KAPPAS = (0.05, 0.1, 0.25, 0.4, 0.5, 0.6, 0.75, 0.9, 0.95)
P_GRID = (1e-12, 1e-8, 1e-4, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.999)


@pytest.fixture(scope="module")
def session():
    return PoolSession()


def wilson_ci(k: int, n: int, z: float = 2.576):
    """99% Wilson score interval for a binomial proportion."""
    p = k / n
    denom = 1 + z ** 2 / n
    center = (p + z ** 2 / (2 * n)) / denom
    half = z * np.sqrt(p * (1 - p) / n + z ** 2 / (4 * n ** 2)) / denom
    return center - half, center + half


# ------------------------------------------------- calibrator family

@pytest.mark.parametrize("kappa", KAPPAS)
def test_kappa_calibrator_has_unit_mean(kappa):
    """E[e(P)] = ∫₀¹ κp^(κ-1) dp = [p^κ]₀¹ = 1 exactly. The
    antiderivative pins the full mass; a fine trapezoid on the
    singularity-free subinterval [0.1, 0.9] must agree with the
    antiderivative there (the implementation IS the density it
    claims)."""
    assert 1.0 ** kappa - 0.0 ** kappa == pytest.approx(1.0)
    p = np.linspace(0.1, 0.9, 200001)
    numeric = _trapz([kappa_calibrator(float(x), kappa) for x in p], p)
    assert numeric == pytest.approx(0.9 ** kappa - 0.1 ** kappa,
                                    abs=1e-6)


@pytest.mark.parametrize("kappa", KAPPAS)
def test_kappa_calibrator_is_decreasing_in_p(kappa):
    vals = [kappa_calibrator(p, kappa) for p in P_GRID]
    assert vals == sorted(vals, reverse=True)


@pytest.mark.parametrize("p", P_GRID)
def test_kappa_calibrator_matches_formula(p):
    for kappa in (0.25, 0.5, 0.75):
        assert kappa_calibrator(p, kappa) == pytest.approx(
            kappa * p ** (kappa - 1.0), rel=1e-12)


@pytest.mark.parametrize("kappa", (-0.5, 0.0, 1.0, 1.5))
def test_kappa_outside_open_unit_interval_raises(kappa):
    with pytest.raises(ValueError, match="kappa"):
        kappa_calibrator(0.5, kappa)
    with pytest.raises(ValueError, match="kappa"):
        log_kappa_evalue(0.5, kappa)


@pytest.mark.parametrize("p", P_GRID)
def test_mixture_matches_numeric_kappa_integration(p):
    """The closed form F(p) = (1 - p + p·ln p)/(p·(ln p)²) must equal
    ∫₀¹ κp^(κ-1) dκ (the uniform mixture over the family)."""
    kappas = np.linspace(1e-6, 1.0 - 1e-6, 200001)
    numeric = _trapz(kappas * p ** (kappas - 1.0), kappas)
    assert mixture_calibrator(p) == pytest.approx(numeric, rel=1e-3)


def test_mixture_has_unit_mean():
    """By Fubini, ∫ₐᵇ F(p) dp = ∫₀¹ (b^κ - a^κ) dκ = (b-1)/ln b -
    (a-1)/ln a; at (a, b) → (0, 1) that is 1 - 0 — unit mean. Check the
    implementation against the closed form on a singularity-free
    subinterval."""
    def mass(x):
        return (x - 1.0) / math.log(x)
    a, b = 0.1, 0.9
    p = np.linspace(a, b, 200001)
    numeric = _trapz([mixture_calibrator(float(x)) for x in p], p)
    assert numeric == pytest.approx(mass(b) - mass(a), abs=1e-6)
    # the endpoints' limits: lim_{a→0} (a-1)/ln a = 0, lim_{b→1} = 1
    assert mass(1e-12) == pytest.approx(0.0, abs=0.04)
    assert mass(1.0 - 1e-12) == pytest.approx(1.0, abs=1e-9)


def test_mixture_limit_at_one_is_half():
    """lim_{p→1} F(p) = 1/2 (l'Hôpital twice); the implementation must
    not 0/0 at the boundary."""
    assert mixture_calibrator(1.0) == pytest.approx(0.5)
    assert mixture_calibrator(1.0 - 1e-9) == pytest.approx(0.5, abs=1e-3)


def test_mixture_is_huge_at_tiny_p():
    assert mixture_calibrator(1e-12) > 1e9
    assert mixture_calibrator(1e-300) > 1e290


@pytest.mark.parametrize("p", P_GRID)
@pytest.mark.parametrize("cal", CALIBRATORS)
def test_log_evalue_consistent_with_linear_calibrator(p, cal):
    lin = (kappa_calibrator(p) if cal == "kappa"
           else mixture_calibrator(p))
    assert math.exp(log_evalue(p, calibrator=cal)) == pytest.approx(
        lin, rel=1e-10)


def test_log_evalue_rejects_unknown_calibrator():
    with pytest.raises(KeyError, match="calibrator"):
        log_evalue(0.5, calibrator="fisher")


@pytest.mark.parametrize("p", (0.0, 1e-12, 0.01, 0.3, 0.5))
def test_two_sided_p_is_symmetric(p):
    assert two_sided_p(p) == pytest.approx(two_sided_p(1.0 - p))
    assert two_sided_p(p) == pytest.approx(min(1.0, 2.0 * p))


def test_two_sided_p_validates_domain():
    assert two_sided_p(0.5) == 1.0
    for bad in (-0.1, 1.1, float("nan")):
        with pytest.raises(ValueError):
            two_sided_p(bad)


def test_two_sided_fold_preserves_uniformity():
    """p₂ = 2·min(p, 1-p) of a Uniform(0,1) is Uniform(0,1) — the fold
    that lets one-sided calibrators spend on BOTH suspect tails without
    breaking the unit-mean guarantee."""
    rng = np.random.default_rng(3)
    u = rng.uniform(size=200000)
    folded = np.array([two_sided_p(p) for p in u])
    hist, _ = np.histogram(folded, bins=20, range=(0.0, 1.0))
    assert hist.min() > 0.8 * len(u) / 20
    assert hist.max() < 1.2 * len(u) / 20


def test_evidence_constants_match_stitch():
    """evidence.py keeps local PASS/FAIL/UNDECIDED copies (stitch
    imports evidence, not the reverse) — they must never drift."""
    assert evidence.PASS == stitch.PASS == "PASS"
    assert evidence.FAIL == stitch.FAIL == "FAIL"
    assert evidence.UNDECIDED == stitch.UNDECIDED == "UNDECIDED"


def test_verdict_engine_registry():
    assert set(stitch.VERDICT_ENGINES) == {"bonferroni", "evalue"}
    assert stitch.verdict_for("bonferroni") is stitch.sequential_verdict
    assert stitch.verdict_for("evalue") is evidence_verdict
    with pytest.raises(KeyError, match="bonferroni"):
        stitch.verdict_for("fisher")


# --------------------------------------------------- engine semantics

def test_empty_results_are_undecided():
    v = evidence_verdict({}, 10, 0.01)
    assert v.decision == UNDECIDED and not v.decided
    assert v.n_checked == 0 and v.log_wealth == 0.0 and v.wealth == 1.0


def test_null_battery_passes_at_completion():
    v = evidence_verdict({i: (0.0, 0.5) for i in range(10)}, 10, 0.01)
    assert v.decision == PASS and v.decided
    assert v.wealth < 1.0                       # e(0.5-ish p) < 1


def test_catastrophic_p_fails_immediately():
    v = evidence_verdict({3: (9.0, 1e-12)}, 10, 0.01)
    assert v.decision == FAIL and v.decided
    assert v.failed_tests == (3,)
    assert v.wealth >= 1.0 / 0.01


def test_high_tail_p_fails_too():
    """TestU01's two-sided suspect convention: p ≈ 1 is as damning as
    p ≈ 0 — the two-sided fold must route it into the calibrator."""
    v = evidence_verdict({2: (9.0, 1.0 - 1e-12)}, 10, 0.01)
    assert v.decision == FAIL and v.failed_tests == (2,)


def test_accumulated_moderate_evidence_fails():
    """No single test is damning but the product crosses 1/alpha —
    the martingale composition the Bonferroni engine cannot express."""
    results = {i: (0.0, 1e-3) for i in range(6)}
    v = evidence_verdict(results, 10, 0.01)
    assert v.decision == FAIL
    single = evidence_verdict({0: (0.0, 1e-3)}, 10, 0.01)
    assert single.decision == UNDECIDED        # one alone is not enough
    assert single.failed_tests == ()


def test_invalid_p_values_are_skipped():
    v = evidence_verdict({0: (1.0, float("nan")), 1: (1.0, -0.5),
                          2: (1.0, 2.0), 3: (0.0, 0.5)}, 10, 0.01)
    assert v.n_checked == 1
    assert v.decision == UNDECIDED


@pytest.mark.parametrize("n_total", (0, -3))
def test_engine_rejects_bad_n_total(n_total):
    with pytest.raises(ValueError, match="n_total"):
        evidence_verdict({}, n_total, 0.01)


@pytest.mark.parametrize("alpha", (0.0, 1.0, -0.2, 1.5))
def test_engine_rejects_bad_alpha(alpha):
    with pytest.raises(ValueError, match="alpha"):
        evidence_verdict({}, 10, alpha)


@pytest.mark.parametrize("band", (-0.1, 1.0, 2.0))
def test_engine_rejects_bad_band(band):
    with pytest.raises(ValueError, match="band"):
        evidence_verdict({}, 10, 0.01, band=band)


def test_band_holds_borderline_cells_open():
    """At completion, wealth inside [band/alpha, 1/alpha) is UNDECIDED
    (borderline) when a band is configured, PASS when it is not."""
    results = {i: (0.0, 0.01) for i in range(4)}       # some evidence
    full = {**results, **{i: (0.0, 0.5) for i in range(4, 10)}}
    closed = evidence_verdict(full, 10, 0.01, band=0.0)
    assert closed.decision == PASS and not closed.borderline
    open_ = evidence_verdict(full, 10, 0.01, band=0.01)
    assert 0.01 / 0.01 <= open_.wealth < 1.0 / 0.01
    assert open_.decision == UNDECIDED and open_.borderline


def test_band_does_not_touch_clear_pass():
    v = evidence_verdict({i: (0.0, 0.5) for i in range(10)}, 10, 0.01,
                         band=0.5)
    assert v.decision == PASS and not v.borderline


def test_trajectory_is_cumulative_in_test_order():
    results = {5: (0.0, 0.2), 1: (0.0, 0.01), 3: (0.0, 0.4)}
    v = evidence_verdict(results, 10, 0.01)
    traj = v.trajectory
    assert len(traj) == 3
    expect = []
    acc = 0.0
    for i in (1, 3, 5):                        # ascending test index
        acc += log_evalue(two_sided_p(results[i][1]))
        expect.append(wealth_from_log(acc))
    assert traj == pytest.approx(tuple(expect))
    assert traj[-1] == pytest.approx(v.wealth)


def test_verdict_str_names_engine_quantities():
    s = str(evidence_verdict({0: (0.0, 1e-12)}, 10, 0.01))
    assert "FAIL" in s and "wealth" in s and "alpha=0.01" in s


def test_log_wealth_never_overflows():
    results = {i: (0.0, 1e-300) for i in range(50)}
    v = evidence_verdict(results, 50, 0.01)
    assert v.decision == FAIL
    assert math.isfinite(v.wealth)              # capped, not inf
    assert all(math.isfinite(w) for w in v.trajectory)


@pytest.mark.parametrize("kappa", (0.2, 0.5, 0.8))
def test_engine_kappa_calibrator_option(kappa):
    v = evidence_verdict({0: (0.0, 1e-14)}, 10, 0.01,
                         calibrator="kappa", kappa=kappa)
    assert v.decision == FAIL
    assert v.log_wealth == pytest.approx(
        log_kappa_evalue(two_sided_p(1e-14), kappa))


# ------------------------------------------------- calibration gates

def test_null_false_fail_rate_within_binomial_ci_of_alpha():
    """Calibration headline: m synthetic null batteries through the
    e-value engine. Ville guarantees P(FAIL) <= alpha; the Wilson CI of
    the observed rate must be consistent with that (lower bound below
    alpha) — the engine is allowed to be conservative, never
    anti-conservative."""
    rng = np.random.default_rng(42)
    n, alpha, m = 10, 0.05, 4000
    fails = 0
    for _ in range(m):
        ps = rng.uniform(size=n)
        v = evidence_verdict({i: (0.0, p) for i, p in enumerate(ps)},
                             n, alpha)
        assert v.decision in (PASS, FAIL)
        fails += v.decision == FAIL
    lo, hi = wilson_ci(fails, m)
    assert lo <= alpha, (fails, m, lo, hi)
    assert fails / m <= alpha, (fails, m)


def test_anytime_false_fail_rate_under_interim_looks():
    """The point of an e-process: look after EVERY result and FAIL the
    moment wealth crosses — the sup over all interim looks must still
    respect alpha (a fixed-sample test abused this way would not)."""
    rng = np.random.default_rng(7)
    n, alpha, m = 10, 0.05, 4000
    crossed = 0
    for _ in range(m):
        ps = rng.uniform(size=n)
        for k in range(1, n + 1):
            v = evidence_verdict(
                {i: (0.0, ps[i]) for i in range(k)}, n, alpha)
            if v.decision == FAIL:
                crossed += 1
                break
    lo, hi = wilson_ci(crossed, m)
    assert lo <= alpha, (crossed, m, lo, hi)


def test_power_moderate_alternative_beats_single_look():
    """Under a diffuse alternative (p ~ Beta(0.3, 1): small but not
    catastrophic) the mixture-martingale engine must actually reject
    most of the time — conservativeness under the null must not mean
    uselessness under the alternative."""
    rng = np.random.default_rng(11)
    n, alpha, m = 10, 0.05, 500
    fails = sum(
        evidence_verdict(
            {i: (0.0, p) for i, p in
             enumerate(rng.beta(0.3, 1.0, size=n) * 0.1)},
            n, alpha).decision == FAIL
        for _ in range(m))
    assert fails / m > 0.8, fails


@pytest.mark.slow
def test_power_gate_randu_fails_crush_within_12_rounds(session):
    """ISSUE gate: randu must FAIL crush under the e-value engine in at
    most 12 of its ~96 rounds — early stopping has to actually engage
    on a catastrophically bad generator."""
    spec = RunSpec("crush", "randu", 9, scale=SCALE, policy="adaptive",
                   stop_on_verdict=True, verdict_engine="evalue")
    res = session.submit(spec).result()
    assert res.verdict.decision == FAIL
    assert res.rounds_run <= 12, res.rounds_run


def test_engines_agree_on_decided_smallcrush_verdicts(session):
    """Fast agreement gate: a complete smallcrush screen decided by both
    engines must decide the same way (PASS the good generator, FAIL
    randu) — the engines differ in WHEN they decide, never on WHAT."""
    spec = RunSpec("smallcrush", ("splitmix64", "randu"), seeds=(7, 7),
                   scale=SCALE)
    res = session.submit(spec).result()
    n = len(session.entries(spec))
    for gen, run in res.runs.items():
        b = stitch.sequential_verdict(run.results, n, 0.01)
        e = evidence_verdict(run.results, n, 0.01)
        assert b.decided and e.decided
        assert b.decision == e.decision, (gen, b.decision, e.decision)


@pytest.mark.slow
def test_engines_agree_on_every_decided_crush_verdict(session):
    """ISSUE gate, benchmarks/early_stop.py's sweep: every generator in
    the registry, complete crush results, both engines.  All decided
    verdicts must match outside the razor-thin margin; inside it the
    documented (DESIGN.md §13) conservatism of the product e-process is
    the ONLY divergence allowed — Bonferroni rejects on a single test's
    p a small factor under its ``alpha/2n`` line, while the product of
    96 e-values stays diluted below ``1/alpha``.  The divergence must
    therefore (a) run in the conservative direction only (never an
    e-value FAIL that Bonferroni calls PASS), and (b) rest on a lone
    marginal test: exactly one Bonferroni-failed test whose p is within
    32x of the per-tail threshold and whose single e-value cannot carry
    the 96-test family on its own (below ``n/alpha``, the e-Bonferroni
    line)."""
    from repro.rng.generators import GENERATORS
    gens = tuple(sorted(GENERATORS))
    spec = RunSpec("crush", gens, seeds=(9,) * len(gens), scale=SCALE)
    res = session.submit(spec).result()
    n = len(session.entries(spec))
    decided_both = agreed = 0
    for gen, run in res.runs.items():
        b = stitch.sequential_verdict(run.results, n, 0.01)
        e = evidence_verdict(run.results, n, 0.01)
        assert b.decided and e.decided, gen
        decided_both += 1
        if b.decision == e.decision:
            agreed += 1
            continue
        # conservative direction only, and only on a razor-thin margin
        assert (b.decision, e.decision) == (FAIL, PASS), (
            gen, b.decision, e.decision)
        assert len(b.failed_tests) == 1, (gen, b.failed_tests)
        minp = min(p for _, p in run.results.values())
        per_tail = 0.01 / (2 * n)
        assert per_tail / 32 < minp < per_tail, (gen, minp, per_tail)
        assert max(le for _, le in e.log_evalues) < math.log(n / 0.01), gen
    assert decided_both == len(gens)
    # the canaries are crisp cases — engines must agree on them, and
    # agreement must hold on all but at most one marginal generator
    for gen in ("randu", "minstd"):
        assert evidence_verdict(res.runs[gen].results, n,
                                0.01).decision == FAIL, gen
    assert agreed >= len(gens) - 1, f"{agreed}/{len(gens)} agreed"


# ---------------------------------------------------- property tests

@settings(max_examples=40, deadline=None)
@given(ps=st.lists(st.floats(1e-9, 1.0 - 1e-9), min_size=1,
                   max_size=12),
       seed=st.integers(0, 2 ** 16))
def test_wealth_is_order_invariant(ps, seed):
    """E-value products commute: any data-independent ordering of the
    same results accumulates the same wealth (within float tolerance) —
    merging partial batteries in any order is sound."""
    import random as _random
    results = {i: (0.0, p) for i, p in enumerate(ps)}
    base = evidence_verdict(results, len(ps), 0.01)
    idx = list(results)
    _random.Random(seed).shuffle(idx)
    shuffled = {i: results[i] for i in idx}
    again = evidence_verdict(shuffled, len(ps), 0.01)
    assert again.log_wealth == pytest.approx(base.log_wealth, abs=1e-9)
    assert again.decision == base.decision


@settings(max_examples=40, deadline=None)
@given(a=st.lists(st.floats(-30.0, 30.0), max_size=8),
       b=st.lists(st.floats(-30.0, 30.0), max_size=8),
       c=st.lists(st.floats(-30.0, 30.0), max_size=8))
def test_combine_log_wealth_commutes_and_associates(a, b, c):
    """Product composition to battery/campaign level: merge is a plain
    sum in log space, so it must commute and associate."""
    assert combine_log_wealth(a + b) == pytest.approx(
        combine_log_wealth(b + a), abs=1e-9)
    left = combine_log_wealth([combine_log_wealth(a + b)] + c)
    right = combine_log_wealth(a + [combine_log_wealth(b + c)])
    assert left == pytest.approx(right, abs=1e-9)
    assert combine_log_wealth([]) == 0.0


@settings(max_examples=40, deadline=None)
@given(ps=st.lists(st.floats(1e-9, 1.0 - 1e-9), min_size=2,
                   max_size=12),
       k=st.integers(1, 12))
def test_wealth_invariant_to_data_independent_stopping(ps, k):
    """Stopping after k results (k chosen before seeing data) yields
    exactly the wealth of the first k e-values — no stopping rule can
    manufacture or destroy evidence (Ville validity's bookkeeping
    half)."""
    k = min(k, len(ps))
    n = len(ps)
    prefix = evidence_verdict({i: (0.0, ps[i]) for i in range(k)},
                              n, 0.01)
    expect = combine_log_wealth(
        [log_evalue(two_sided_p(p)) for p in ps[:k]])
    assert prefix.log_wealth == pytest.approx(expect, abs=1e-9)
    # a FAIL at the stop is a FAIL of every continuation (products of
    # later e-values can shrink wealth, but the CROSSING already bound
    # the error budget — the engine must keep it)
    if prefix.decision == FAIL:
        assert prefix.wealth >= 1.0 / 0.01


@settings(max_examples=25, deadline=None)
@given(codes=st.lists(st.sampled_from([0, 1, 2]), min_size=2,
                      max_size=12))
def test_ledger_roundtrip_preserves_wealth_and_decisions(
        tmp_path_factory, codes):
    """v3 ledger property: save/load is the identity on (decisions,
    log_wealth, engine, continuations) for arbitrary decision states —
    what makes continuation resume-safe. (``tmp_path_factory`` — a
    session-scoped fixture — keeps the real hypothesis's health check
    quiet.)"""
    from repro.core.api import CampaignLedger
    spec = CampaignSpec("smallcrush", ("splitmix64",),
                        n_streams=len(codes), seed=3,
                        waves=(SCALE,), verdict_engine="evalue")
    led = CampaignLedger.fresh(spec)
    led.decisions = np.asarray(codes, np.int8)
    led.log_wealth = np.linspace(-2.0, 5.0, len(codes))
    led.continuations = 1
    path = str(tmp_path_factory.mktemp("evledger") / "prop.ledger")
    led.save(path)
    back = CampaignLedger.load(path)
    assert back.version == 3 and back.engine == "evalue"
    assert back.continuations == 1
    np.testing.assert_array_equal(back.decisions, led.decisions)
    np.testing.assert_allclose(back.log_wealth, led.log_wealth)
    assert back.matches(spec)


# ------------------------------------------------- battery end to end

def test_evalue_battery_pass_and_wealth_history(session):
    spec = RunSpec("smallcrush", "splitmix64", 3, scale=SCALE,
                   verdict_engine="evalue")
    handle = session.submit(spec)
    res = handle.result()
    v = res.verdict
    assert isinstance(v, EvidenceVerdict)
    assert v.decision == PASS
    assert v.wealth < 1.0 / spec.alpha
    # one wealth sample per dispatched round, ending at the final wealth
    assert len(handle.wealth_history[0]) == res.rounds_run > 0
    assert handle.wealth_history[0][-1] == pytest.approx(v.wealth)


def test_evalue_adaptive_randu_stops_early(session):
    spec = RunSpec("smallcrush", "randu", 7, scale=SCALE,
                   policy="adaptive", stop_on_verdict=True,
                   verdict_engine="evalue")
    res = session.submit(spec).result()
    assert res.verdict.decision == FAIL
    assert res.rounds_run < res.plan_rounds     # pending rounds cancelled
    assert res.verdict.wealth >= 1.0 / spec.alpha


def test_evalue_checkpoint_v5_records_wealth(session, tmp_path):
    ck = str(tmp_path / "wealth.ck")
    spec = RunSpec("smallcrush", "splitmix64", 3, scale=SCALE,
                   checkpoint_path=ck, verdict_engine="evalue")
    res = session.submit(spec).result()
    saved = Checkpoint.load(ck)
    assert saved.version == 5 and saved.engine == "evalue"
    assert saved.log_wealth is not None and saved.log_wealth.shape == (1,)
    assert float(saved.log_wealth[0]) == pytest.approx(
        res.verdict.log_wealth)
    # resume with the same spec: nothing re-executes, verdict identical
    res2 = session.submit(spec).result()
    assert res2.rounds_run == 0
    assert res2.verdict.log_wealth == pytest.approx(
        res.verdict.log_wealth)


def test_resume_refuses_cross_engine_checkpoint(session, tmp_path):
    """Satellite gate: a Bonferroni stop_on_verdict checkpoint resumed
    under ``verdict_engine="evalue"`` is a typed refusal naming both
    engines and alphas — their decisions are not comparable."""
    ck = str(tmp_path / "engine.ck")
    spec = RunSpec("smallcrush", "splitmix64", 3, scale=SCALE,
                   policy="adaptive", stop_on_verdict=True,
                   checkpoint_path=ck)
    session.submit(spec).result()
    import dataclasses
    cross = dataclasses.replace(spec, verdict_engine="evalue")
    with pytest.raises(VerdictEngineMismatch) as exc:
        session.submit(cross)
    msg = str(exc.value)
    assert "'bonferroni'" in msg and "'evalue'" in msg
    assert "alpha=0.01" in msg
    assert issubclass(VerdictEngineMismatch, ValueError)
    # the same checkpoint under its own engine resumes cleanly: no jobs
    # re-execute (plan_rounds == 0), and the stop_on_verdict bookkeeping
    # adopts the checkpoint's sequential-look round count unchanged
    res = session.submit(spec).result()
    assert res.plan_rounds == 0
    assert res.rounds_run == 10
    assert res.verdict.decision == PASS


def test_tampered_checkpoint_error_names_engine_and_alphas(session,
                                                           tmp_path):
    """Satellite 4: the verdict cross-check's error must carry the
    engine name and BOTH alphas (checkpoint's and spec's) so a
    different-spec resume is diagnosable from the message alone."""
    ck = str(tmp_path / "tamper.ck")
    spec = RunSpec("smallcrush", "splitmix64", 3, scale=SCALE,
                   policy="adaptive", stop_on_verdict=True,
                   checkpoint_path=ck)
    session.submit(spec).result()
    leaves = ckpt_io.load_flat(ck)
    dec = np.asarray(leaves[4], np.int8).copy()
    dec[0] = 2                                  # flip PASS -> FAIL code
    ckpt_io.save(ck, leaves[:4] + [dec] + leaves[5:])
    with pytest.raises(ValueError) as exc:
        session.submit(spec)
    msg = str(exc.value)
    assert "engine 'bonferroni'" in msg
    assert "checkpoint alpha=0.01" in msg and "at alpha=0.01" in msg


# ------------------------------------------------ campaign continuation

def _continuation_spec(tmp_path, name="cont"):
    return CampaignSpec(
        "smallcrush", ("splitmix64", "pcg32"), n_streams=2, seed=11,
        waves=(SCALE,), stream_check=False, verdict_engine="evalue",
        continue_band=1e-4, max_continuations=1,
        ledger_path=str(tmp_path / f"{name}.ledger"))


def test_campaign_borderline_cells_reopen_next_wave(session, tmp_path):
    """ISSUE acceptance: a borderline cell (wealth within the band of
    1/alpha at the last wave) is re-opened in a ``continue1`` phase on
    fresh stream words instead of force-decided; the continuation
    budget then force-decides it."""
    camp = Campaign(session, _continuation_spec(tmp_path))
    assert [p.name for p in camp.phases()] == ["x0.0625"]
    res = camp.run()
    assert res.continuations == 1
    assert res.phase_names == ["x0.0625", "continue1"]
    assert "continue1" in res.phase_names
    assert len(res.survivors) + len(res.knockouts) == len(res.cells)
    assert res.log_wealth is not None and res.wealth is not None
    assert res.log_wealth.shape == (4,)


def test_campaign_continuation_never_flips_decided_cells(session,
                                                         tmp_path):
    """Satellite 2's campaign property, end to end: any cell decided
    BEFORE the continuation keeps its decision (and its decided_phase)
    after the continuation runs."""
    camp = Campaign(session, _continuation_spec(tmp_path, "flip"))
    assert camp.run_next_phase()                # wave completes
    pre = camp.ledger.decisions.copy()
    pre_phase = camp.ledger.decided_phase.copy()
    decided = pre != 0
    assert decided.any()                        # at least one decided cell
    while camp.run_next_phase():
        pass
    post = camp.ledger.decisions
    np.testing.assert_array_equal(post[decided], pre[decided])
    np.testing.assert_array_equal(
        camp.ledger.decided_phase[decided], pre_phase[decided])
    assert (post != 0).all()                    # and the rest got decided


def test_campaign_continuation_resume_is_bitwise(session, tmp_path):
    """ISSUE acceptance: a campaign stopped mid-continuation resumes
    from the v3 ledger bitwise — the resumed run replays 0 completed
    rounds and lands on identical decisions and wealth."""
    spec = _continuation_spec(tmp_path, "resume")
    camp = Campaign(session, spec)
    res1 = camp.run()
    assert res1.continuations == 1
    # a fresh Campaign over the finished ledger replays nothing
    again = Campaign(session, spec)
    assert again.ledger.continuations == 1
    assert [p.name for p in again.phases()] == res1.phase_names
    assert again.complete
    res2 = again.run()
    assert res2.rounds_run == 0
    np.testing.assert_array_equal(res2.decisions, res1.decisions)
    np.testing.assert_array_equal(res2.log_wealth, res1.log_wealth)
    assert res2.continuations == res1.continuations == 1


def test_campaign_mid_wave_continuation_resume(session, tmp_path):
    """Mid-wave variant: kill the campaign right AFTER the ledger
    records the continuation opening, resume — the continuation phase
    list is reconstructed from the ledger (phases() is a pure function
    of (spec, ledger)) and completed phases replay 0 rounds."""
    spec = _continuation_spec(tmp_path, "midwave")
    camp = Campaign(session, spec)
    assert camp.run_next_phase()                # wave 0
    first_rounds = camp.rounds_run
    assert first_rounds > 0
    assert camp.run_next_phase()                # opens + runs continue1
    assert camp.ledger.continuations == 1
    resumed = Campaign(session, spec)
    assert [p.name for p in resumed.phases()] == ["x0.0625", "continue1"]
    res = resumed.run()
    assert res.rounds_run == 0                  # everything came from disk
    np.testing.assert_array_equal(res.decisions, camp.ledger.decisions)


def test_campaign_spec_validates_continuation_knobs():
    with pytest.raises(ValueError, match="continue_band"):
        CampaignSpec("smallcrush", ("splitmix64",), waves=(SCALE,),
                     verdict_engine="evalue", continue_band=1.5)
    with pytest.raises(ValueError, match="max_continuations"):
        CampaignSpec("smallcrush", ("splitmix64",), waves=(SCALE,),
                     verdict_engine="evalue", max_continuations=-1)
    with pytest.raises(KeyError, match="verdict engine"):
        CampaignSpec("smallcrush", ("splitmix64",), waves=(SCALE,),
                     verdict_engine="fisher")


def test_bonferroni_campaign_has_no_wealth(session, tmp_path):
    spec = CampaignSpec("smallcrush", ("splitmix64",), n_streams=1,
                        seed=5, waves=(SCALE,), stream_check=False,
                        ledger_path=str(tmp_path / "bon.ledger"))
    res = Campaign(session, spec).run()
    assert res.log_wealth is None and res.wealth is None
    assert res.continuations == 0
    assert "continue1" not in res.phase_names


# ------------------------------------------------------- serve layer

def test_cell_digest_engine_fold_is_backward_compatible():
    """A Bonferroni digest must be byte-identical to the historical
    (pre-engine) digest — cached fleets keep their history — while an
    e-value digest differs, so cached Bonferroni results can never
    answer e-value submissions."""
    from repro.serve.cache import cell_digest
    base = ("smallcrush", 0.0625, "splitmix64", 7, 0, 0.01, "reference")
    assert cell_digest(*base) == cell_digest(*base, engine="bonferroni")
    assert cell_digest(*base, engine="evalue") != cell_digest(*base)
    assert cell_digest(*base, engine="evalue") == cell_digest(
        *base, engine="evalue")


def test_spec_cells_fold_the_spec_engine():
    from repro.serve.queue import admission_key, spec_cells
    bon = RunSpec("smallcrush", "splitmix64", 7, scale=SCALE)
    ev = RunSpec("smallcrush", "splitmix64", 7, scale=SCALE,
                 verdict_engine="evalue")
    assert spec_cells(bon)[0].digest != spec_cells(ev)[0].digest
    assert admission_key(bon) != admission_key(ev)


def test_cache_entry_v2_roundtrip_and_v1_read(tmp_path):
    from repro.serve.cache import CACHE_VERSION, CacheEntry
    assert CACHE_VERSION == 2
    results = {i: (1.0, 0.4) for i in range(10)}
    entry = CacheEntry.from_results(results, 10, 0.01, engine="evalue")
    assert entry.engine == "evalue"
    assert isinstance(entry.verdict(), EvidenceVerdict)
    path = str(tmp_path / "v2.ck")
    entry.save(path)
    leaves = ckpt_io.load_flat(path)
    assert len(leaves) == 9 and int(leaves[0]) == CACHE_VERSION
    back = CacheEntry.load(path)
    assert back.engine == "evalue" and back.version == 2
    assert back.decision == entry.decision == PASS
    # v1 read path: strip the engine leaf, rewrite version 1
    v1 = str(tmp_path / "v1.ck")
    ckpt_io.save(v1, [np.int64(1)] + leaves[1:8])
    old = CacheEntry.load(v1)
    assert old.version == 1 and old.engine == "bonferroni"
    assert not isinstance(old.verdict(), EvidenceVerdict)
    # malformed layouts stay refused
    bad = str(tmp_path / "bad.ck")
    ckpt_io.save(bad, leaves[:5])
    with pytest.raises(ValueError, match="leaves"):
        CacheEntry.load(bad)


def test_cached_bonferroni_never_answers_evalue_submission(tmp_path):
    """The whole point of folding the engine into the digest: fill the
    cache under one engine, resubmit the identical cell under the
    other — guaranteed miss."""
    from repro.serve.cache import CacheEntry, ResultCache
    from repro.serve.queue import spec_cells
    cache = ResultCache(str(tmp_path / "cache"))
    bon = RunSpec("smallcrush", "splitmix64", 7, scale=SCALE)
    ev = RunSpec("smallcrush", "splitmix64", 7, scale=SCALE,
                 verdict_engine="evalue")
    results = {i: (1.0, 0.4) for i in range(10)}
    cache.put(spec_cells(bon)[0].digest,
              CacheEntry.from_results(results, 10, 0.01))
    assert cache.get(spec_cells(bon)[0].digest) is not None
    assert cache.get(spec_cells(ev)[0].digest) is None
    assert cache.hits == 1 and cache.misses == 1


def test_serve_ticket_verdicts_use_spec_engine(session):
    from repro.serve import SubmissionQueue
    queue = SubmissionQueue(session=session)
    spec = RunSpec("smallcrush", "splitmix64", 7, scale=SCALE,
                   verdict_engine="evalue")
    t = queue.submit(spec)
    queue.drain()
    res = t.result()
    assert isinstance(res.verdict, EvidenceVerdict)
    assert res.verdict.decision == PASS
    # a repeat submission under the SAME engine is the O(1) cache path
    t2 = queue.submit(spec)
    assert t2.done and t2.cache_hits == 1
    assert isinstance(t2.result().verdict, EvidenceVerdict)

"""End-to-end behaviour tests for the paper's system (battery + pool),
on the public session API (RunSpec / PoolSession / BatteryRun)."""
import numpy as np
import pytest

from repro.core.api import PoolSession, RunSpec
from repro.core.battery import build_battery
from repro.core.pool import make_batch_runner, run_sequential
from repro.core.queue import run_battery
from repro.core.scheduler import make_plan, replan
from repro.core import stitch
from repro.launch.mesh import make_pool_mesh
from repro.rng.generators import GEN_IDS

SCALE = 0.125  # CI-sized battery


@pytest.fixture(scope="module")
def session():
    return PoolSession()


@pytest.fixture(scope="module")
def smallcrush():
    return build_battery("smallcrush", SCALE)


def test_battery_sizes():
    assert len(build_battery("smallcrush", SCALE)) == 10
    assert len(build_battery("crush", SCALE)) == 96
    assert len(build_battery("bigcrush", SCALE)) == 106


def test_good_generator_passes(session):
    res = session.submit(RunSpec("smallcrush", "splitmix64", 7,
                                 scale=SCALE)).result()
    assert "SUSPECT" not in res.report
    assert len(res.results) == 10


def test_randu_fails(session):
    res = session.submit(RunSpec("smallcrush", "randu", 7,
                                 scale=SCALE)).result()
    assert res.report.count("SUSPECT") >= 2          # known-bad canary


def test_pool_matches_sequential(smallcrush, session):
    """The paper's accuracy criterion (§11): distributed results identical
    to the single-worker run of the same individual-test semantics."""
    stats_seq, ps_seq = run_sequential(smallcrush, 3, GEN_IDS["pcg32"])
    res = session.submit(RunSpec("smallcrush", "pcg32", 3,
                                 scale=SCALE)).result()
    for i in range(10):
        assert np.isclose(res.results[i][0], float(stats_seq[i]), rtol=1e-6)
        assert np.isclose(res.results[i][1], float(ps_seq[i]), rtol=1e-6)


def test_queue_shim_matches_session(session):
    """The classic run_battery surface is a thin driver over the session
    API and must produce bitwise-identical results."""
    res_old = run_battery("smallcrush", "splitmix64", 5,
                          make_pool_mesh(), scale=SCALE)
    res_new = session.submit(RunSpec("smallcrush", "splitmix64", 5,
                                     scale=SCALE)).result()
    assert res_old.results == res_new.results
    assert res_old.report == res_new.report


def test_results_worker_count_invariant(smallcrush):
    """Counter-based streams: results must not depend on pool width or
    scheduling mode (what makes hold/release + speculation reconcilable)."""
    mesh = make_pool_mesh()
    runner = make_batch_runner(smallcrush, mesh)
    outs = []
    for mode in ("roundrobin", "lpt"):
        plan = make_plan([e.cost for e in smallcrush], 1, mode)
        stats, ps = runner(np.asarray(plan.assignment), np.int32(5),
                           np.int32(GEN_IDS["splitmix64"]))
        res = stitch.fold(plan.assignment, np.asarray(stats),
                          np.asarray(ps))
        outs.append([res[i] for i in range(10)])
    assert outs[0] == outs[1]


def test_checkpoint_restart(tmp_path, session):
    ck = str(tmp_path / "battery.ck")
    spec = RunSpec("smallcrush", "splitmix64", 11, scale=SCALE,
                   checkpoint_path=ck)
    res1 = session.submit(spec).result()
    # restart: everything already done -> zero rounds run
    res2 = session.submit(spec).result()
    assert res2.rounds_run == 0
    assert res1.results == res2.results


def test_run_handle_verbs(session):
    """submit/poll/held/release/stream — the HTCondor-shaped lifecycle."""
    run = session.submit(RunSpec("smallcrush", "splitmix64", 2, scale=SCALE))
    assert run.pending_rounds > 0 and not run.done
    first = run.poll()
    assert first["rounds_run"] == 1 and first["state"] in ("running", "done")
    for status in run.stream():
        pass
    assert run.held() == []                      # deterministic kernels
    assert run.release() == 0
    res = run.result()
    assert len(res.results) == 10 and run.done


def test_hold_release_replan():
    """HELD jobs (invalid results) are re-planned, not lost."""
    results = {i: (1.0, 0.5) for i in range(10)}
    results[3] = (float("nan"), 0.5)       # held
    results.pop(7)                          # missing
    held = stitch.missing(results, 10)
    assert held == [3, 7]
    plan = replan(held, [1.0] * 10, 4)
    covered = sorted(int(i) for i in plan.assignment.ravel() if i >= 0)
    assert covered == [3, 7]


def test_report_format(smallcrush):
    rep = stitch.report(smallcrush, {0: (1.0, 0.5)}, "splitmix64", 1)
    assert "MISSING/HELD" in rep            # 9 tests have no results
    assert "splitmix64" in rep

"""The fault-domain layer (DESIGN.md §12): deterministic fault
injection, the result sanity gate, worker health/quarantine, retry
backoff, and the graceful-degradation invariant.

The headline property, asserted per fault kind and for composed plans:
any fault plan that leaves at least one healthy worker yields stitched
p-values BITWISE identical to the fault-free run — faults cost retry
rounds, never correctness. Multi-worker behaviour (``lose_worker``,
quarantine, the degraded daemon) runs as a subprocess scenario
(tests/faults_scenario.py) because the forced host-device count must be
set before jax initializes."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.api import PoolSession, RunSpec
from repro.core.faults import (FAULT_KINDS, FaultInjector, FaultPlan,
                               FaultRule, WorkerHealth, _bit_flip)
from repro.core.policies import RetryBudgetExhausted, RetryPolicy

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCALE = 0.0625


@pytest.fixture(scope="module")
def session():
    return PoolSession()


@pytest.fixture(scope="module")
def clean(session):
    """The fault-free baseline every parity test compares against."""
    return session.submit(
        RunSpec("smallcrush", "splitmix64", 7, scale=SCALE)).result()


def chaos(session, rules, retry=None, **kw):
    """Submit the baseline spec with a fault plan; return the handle."""
    return session.submit(
        RunSpec("smallcrush", "splitmix64", 7, scale=SCALE,
                retry=retry or RetryPolicy(),
                inject=FaultPlan(rules=tuple(rules)), **kw))


# ------------------------------------------------------- plan validation

def test_fault_rule_validation():
    with pytest.raises(ValueError):
        FaultRule("explode")
    with pytest.raises(ValueError):
        FaultRule("evict", p=0.0)
    with pytest.raises(ValueError):
        FaultRule("evict", p=1.5)
    with pytest.raises(ValueError):
        FaultRule("evict", round=-1)
    with pytest.raises(ValueError):
        FaultRule("evict", slot=-2)
    with pytest.raises(ValueError):
        FaultRule("straggle", delay_s=-1.0)
    with pytest.raises(ValueError):
        FaultRule("lose_worker", width=0)
    assert FaultRule("evict").p == 1.0


def test_fault_plan_json_roundtrip(tmp_path):
    plan = FaultPlan(seed=9, rules=(
        FaultRule("evict", round=0, slot=1),
        FaultRule("corrupt", job=3, p=0.5),
        FaultRule("straggle", round=2, delay_s=7.5),
        FaultRule("lose_worker", round=1, width=2)))
    assert FaultPlan.from_dict(plan.to_dict()) == plan
    path = str(tmp_path / "plan.json")
    plan.save(path)
    assert FaultPlan.load(path) == plan
    # defaults are elided from the wire shape
    d = FaultRule("evict").to_dict()
    assert d == {"kind": "evict"}
    with pytest.raises(ValueError):
        FaultPlan.from_dict({"rules": [{"kind": "explode"}]})


def test_runspec_rejects_non_plan_inject():
    with pytest.raises(TypeError):
        RunSpec("smallcrush", "splitmix64", 7, inject={"seed": 0})


# ------------------------------------------------ deterministic drawing

def test_probabilistic_draws_replay_from_plan_and_seed():
    plan = FaultPlan(seed=11, rules=(FaultRule("evict", p=0.5),))
    row = np.arange(4)
    a, b = FaultInjector(plan), FaultInjector(plan)
    hist_a = [a.matches(r, row) for r in range(64)]
    hist_b = [b.matches(r, row) for r in range(64)]
    assert hist_a == hist_b                     # bit-for-bit replay
    fired = sum(len(m) for m in hist_a)
    assert 0 < fired < 64 * 4                   # actually Bernoulli(.5)
    other = FaultInjector(FaultPlan(seed=12, rules=plan.rules))
    assert [other.matches(r, row) for r in range(64)] != hist_a


def test_idle_slots_never_fault():
    inj = FaultInjector(FaultPlan(rules=(FaultRule("evict"),)))
    row = np.asarray([3, -1, 5])
    assert [(s) for _i, _r, s in inj.matches(0, row)] == [0, 2]


def test_bit_flip_always_escapes_the_unit_interval():
    """The corruption model must be gate-detectable for EVERY valid p:
    flipping the top exponent bit maps [0, 1] outside [0, 1]."""
    for p in (0.0, 5e-324, 1e-300, 1e-9, 0.25, 0.5, 0.9999, 1.0):
        bad = _bit_flip(p)
        assert not (np.isfinite(bad) and 0.0 <= bad <= 1.0), (p, bad)


def test_worker_health_streaks():
    h = WorkerHealth()
    h.record(0, True)
    h.record(0, True)
    h.record(1, False)
    assert h.consecutive(0) == 2 and h.consecutive(1) == 0
    assert h.flaky(2) == [0]
    h.record(0, False)                          # clean round resets
    assert h.flaky(2) == [] and h.total_faults == 2
    h.reset()
    assert h.consecutive(0) == 0


# -------------------------------------- per-kind bitwise parity (W = 1)

def test_evict_parity(session, clean):
    h = chaos(session, [FaultRule("evict", round=0)])
    res = h.result()
    assert res.results == clean.results         # bitwise
    assert res.verdict.decision == clean.verdict.decision
    assert res.retries == 1
    assert [e.kind for e in h.fault_events] == ["evict"]


def test_corrupt_parity_and_sanity_gate(session, clean):
    h = chaos(session, [FaultRule("corrupt", round=0)])
    res = h.result()
    assert res.results == clean.results
    kinds = [e.kind for e in h.fault_events]
    assert kinds == ["corrupt", "corrupt_result"]
    gated = h.fault_events[1]
    assert gated.rule == -1 and "must be finite" in gated.detail
    assert res.retries == 1                     # HELD + retried, silently


def test_straggle_past_deadline_goes_held(session, clean):
    h = chaos(session, [FaultRule("straggle", round=0, delay_s=60.0)],
              retry=RetryPolicy(deadline=30.0))
    res = h.result()
    assert res.results == clean.results
    assert res.retries == 1
    (ev,) = h.fault_events
    assert ev.kind == "straggle" and "HELD" in ev.detail


def test_straggle_without_deadline_is_ledger_only(session, clean):
    h = chaos(session, [FaultRule("straggle", round=0, delay_s=60.0)])
    res = h.result()
    assert res.results == clean.results
    assert res.retries == 0                     # simulated latency only
    (ev,) = h.fault_events
    assert "no deadline set" in ev.detail


def test_composed_plan_parity(session, clean):
    h = chaos(session, [FaultRule("evict", round=0),
                        FaultRule("corrupt", round=1),
                        FaultRule("straggle", round=2, delay_s=60.0)],
              retry=RetryPolicy(max_retries=3, deadline=30.0))
    res = h.result()
    assert res.results == clean.results
    assert res.verdict.decision == clean.verdict.decision
    kinds = {e.kind for e in h.fault_events}
    assert kinds == {"evict", "corrupt", "corrupt_result", "straggle"}


def test_fault_ledger_replays_bit_for_bit(session):
    """Same (plan, seed) against the same schedule: identical ledgers."""
    rules = [FaultRule("corrupt", p=0.5, slot=0)]
    retry = RetryPolicy(max_retries=8)
    a = chaos(session, rules, retry=retry)
    ra = a.result()
    b = chaos(session, rules, retry=retry)
    rb = b.result()
    assert [e.to_dict() for e in a.fault_events] \
        == [e.to_dict() for e in b.fault_events]
    assert ra.results == rb.results


def test_checkpoint_resume_mid_fault(tmp_path, clean):
    """Crash after the faulted round, resume in a fresh session: the
    stitched results still reconcile bitwise with the clean run."""
    ck = str(tmp_path / "chaos.ck")
    spec = RunSpec("smallcrush", "splitmix64", 7, scale=SCALE,
                   checkpoint_path=ck,
                   inject=FaultPlan(rules=(FaultRule("evict", round=0),)))
    s1 = PoolSession()
    h1 = s1.submit(spec)
    h1.poll()                                   # round 0: the eviction
    assert [e.kind for e in h1.fault_events] == ["evict"]
    del h1                                      # "crash" mid-battery
    res = PoolSession().submit(spec).result()
    assert res.results == clean.results


# ------------------------------------------------- exhaustion semantics

def test_exhaustion_raises_with_held_jobs(session):
    h = chaos(session, [FaultRule("corrupt", job=0)],
              retry=RetryPolicy(max_retries=1))
    with pytest.raises(RetryBudgetExhausted) as ei:
        h.result()
    assert ei.value.held == [0]
    assert ei.value.retries == 1
    assert "retry budget exhausted" in str(ei.value)


def test_exhaustion_nonraising_drive_gives_up_quietly(session):
    h = chaos(session, [FaultRule("corrupt", job=0)],
              retry=RetryPolicy(max_retries=1))
    h.drive(raise_on_exhausted=False)
    assert h.held() == [0]
    assert h.driver_retries == 1


def test_manual_release_is_budget_free_under_faults(session):
    """condor_release by hand never spends the driver budget — even a
    zero-budget policy lets a user hand-release until the transient
    fault clears (round indices advance, so a round-pinned rule cannot
    re-fire on the retry)."""
    h = chaos(session, [FaultRule("evict", round=0)],
              retry=RetryPolicy(max_retries=0))
    while h._queue:
        h.poll()
    assert h.held() and h.release() > 0
    res = h.result()                            # nothing left to retry
    assert h.driver_retries == 0 and res.retries == 1


# ------------------------------------------------- retry policy surface

def test_retry_policy_validation():
    for bad in (dict(max_retries=-1), dict(backoff_base=-0.1),
                dict(backoff_mult=0.5), dict(backoff_max=-1.0),
                dict(deadline=0.0), dict(quarantine_after=0)):
        with pytest.raises(ValueError):
            RetryPolicy(**bad)


def test_backoff_deterministic_and_capped():
    p = RetryPolicy(backoff_base=1.0, backoff_mult=2.0, backoff_max=5.0)
    delays = [p.backoff_for(a) for a in range(8)]
    assert delays == [p.backoff_for(a) for a in range(8)]
    assert all(d <= 5.0 for d in delays)
    assert delays[-1] == 5.0                    # cap binds eventually
    # jittered exponential: within [base*mult^a, 1.1 * that]
    assert 1.0 <= delays[0] <= 1.1 and 2.0 <= delays[1] <= 2.2
    assert RetryPolicy().backoff_for(3) == 0.0  # off by default


# ------------------------------------- multi-worker scenario (W = 4)

@pytest.fixture(scope="module")
def scenario(tmp_path_factory):
    """Run the 4-device subprocess scenario once; share its JSON verdict."""
    tmp = str(tmp_path_factory.mktemp("faults"))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)                  # the scenario forces its own
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "faults_scenario.py"),
         tmp], capture_output=True, text=True, env=env, check=True)
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_lose_worker_bitwise(scenario):
    assert scenario["lose_worker_bitwise"]
    assert scenario["lose_worker_final_w"] == 3
    assert scenario["lose_worker_events"] == ["lose_worker"]


def test_quarantine_walks_pool_down_bitwise(scenario):
    assert scenario["quarantine_bitwise"]
    assert scenario["quarantine_verdict"]
    assert len(scenario["quarantines"]) >= 2    # 4 -> 3 -> 2
    assert scenario["final_workers"] < 4
    assert scenario["quarantines"][0]["slots"] == [1]


def test_degraded_daemon_keeps_serving(scenario):
    assert scenario["serve_state"]              # ticket DONE, not hung
    assert scenario["serve_bitwise"]
    assert scenario["serve_status"] == "degraded"
    assert scenario["serve_workers"] < 4

"""BitSource layer tests (DESIGN.md §11): the generator plugin
registry (stable ids, duplicate hard error, compiled-switch reuse,
serve-restart re-registration), captured-bitstream ingestion (bitwise
battery + campaign parity against the generator that produced the
bits, typed bounds errors), the content-addressed cache behaviour a
capture must have (same bytes HIT with zero dispatches, different
bytes MISS), the canonical offset convention, and the v4 checkpoint /
v2 campaign-ledger source-identity wire upgrades."""
import os

import numpy as np
import pytest

from repro.ckpt import io as ckpt_io
from repro.core import stitch
from repro.core.api import (CAMPAIGN_LEDGER_VERSION, CKPT_VERSION,
                            CampaignLedger, CampaignSpec, Checkpoint,
                            PoolSession, RunSpec)
from repro.core.campaign import Campaign
from repro.rng import generators as G
from repro.rng.sources import (CapturedBitsError, CapturedSource,
                               GeneratorSource, OffsetNotSupportedError,
                               capture_generator, counter_based_names,
                               register_generator, registry_size,
                               require_offsetable, resolve_source,
                               unregister_generator)
from repro.serve import SubmissionQueue

SCALE = 0.01
STRIDE = 1 << 15                     # words per captured stream shard


def _spec(src, seed=7, **kw):
    kw.setdefault("scale", SCALE)
    return RunSpec("smallcrush", sources=(src,), seeds=(seed,), **kw)


@pytest.fixture(scope="module")
def session():
    return PoolSession()


@pytest.fixture(scope="module")
def capture(tmp_path_factory):
    """A splitmix64 capture wide/deep enough for every test here."""
    td = tmp_path_factory.mktemp("capture")
    return capture_generator("splitmix64", str(td / "cap.npy"), seed=7,
                             n_streams=16, stride=STRIDE)


# ------------------------------------------------------------- resolution

def test_resolve_source_grammar(capture, tmp_path):
    src = resolve_source("splitmix64")
    assert isinstance(src, GeneratorSource) and not src.captured
    assert resolve_source(src) is src           # BitSource passthrough
    cap = resolve_source(f"file:{capture}")
    assert isinstance(cap, CapturedSource) and cap.captured
    assert cap.fmt == "npy" and cap.name == "cap:cap"
    raw_path = str(tmp_path / "bits.dat")
    np.arange(8, dtype="<u4").tofile(raw_path)
    raw = resolve_source(f"file:{raw_path}:u32")
    assert isinstance(raw, CapturedSource) and raw.fmt == "u32"
    # an unknown suffix is part of the path (paths may contain colons)
    with pytest.raises(FileNotFoundError):
        resolve_source(f"file:{capture}:bogus")
    with pytest.raises(TypeError):
        resolve_source(123)


def test_registry_views_stay_live():
    """The back-compat GENERATORS/GEN_IDS/COUNTER_BASED views derive
    from the live registry: ids are dense and registration-ordered,
    and the non-counter-based complement is exactly mwc."""
    assert G.GEN_IDS["splitmix64"] == 0
    assert sorted(G.GEN_IDS.values()) == list(range(registry_size()))
    assert set(G.GENERATORS) - set(G.COUNTER_BASED) == {"mwc"}
    assert counter_based_names() == G.COUNTER_BASED


def test_duplicate_registration_is_hard_error():
    with pytest.raises(ValueError, match="already registered"):
        register_generator("splitmix64", G.splitmix64_block,
                           counter_based=True)
    with pytest.raises(TypeError):              # declaration is required
        register_generator("nodecl", G.splitmix64_block)


def test_unregister_only_pops_the_last_lane():
    register_generator("tail_a", G.splitmix64_block, counter_based=True)
    register_generator("tail_b", G.splitmix64_block, counter_based=True)
    try:
        with pytest.raises(ValueError, match="most recently"):
            unregister_generator("tail_a")
    finally:
        unregister_generator("tail_b")
        unregister_generator("tail_a")
    with pytest.raises(KeyError):
        unregister_generator("tail_a")


def test_registered_generator_joins_switch_without_retracing(session):
    """A plugin generator gets a NEW (wider) switch without retracing
    the executables existing widths already compiled — and those stay
    live for the built-in lanes afterwards."""
    r_base = session.submit(_spec("splitmix64")).result()
    t0 = session.total_traces
    session.submit(_spec("pcg32")).result()     # same width: reused
    assert session.total_traces == t0
    register_generator("ext_sm64", G.splitmix64_block,
                       counter_based=True)
    try:
        r_ext = session.submit(_spec("ext_sm64")).result()
        assert session.total_traces == t0 + 1   # exactly one wider trace
        # the clone of splitmix64's block is bitwise splitmix64
        assert r_ext.results == r_base.results
        session.submit(_spec("lcg64")).result()
        assert session.total_traces == t0 + 1   # old widths still cached
    finally:
        unregister_generator("ext_sm64")


# --------------------------------------------------- the offset convention

def test_offset_convention_single_gate():
    """``offset=None`` and 0 always pass the gate; a non-zero offset on
    a non-counter-based source raises the SAME typed error everywhere
    (RunSpec, CampaignSpec, the gate itself)."""
    mwc = GeneratorSource("mwc")
    require_offsetable(mwc, None)
    require_offsetable(mwc, 0)
    with pytest.raises(OffsetNotSupportedError):
        require_offsetable(mwc, 64)
    assert issubclass(OffsetNotSupportedError, ValueError)
    with pytest.raises(OffsetNotSupportedError):
        RunSpec("smallcrush", "mwc", seeds=(7,), scale=SCALE, offsets=64)
    with pytest.raises(ValueError, match="COUNTER_BASED"):
        CampaignSpec("smallcrush", generators=("mwc",), n_streams=2)


def test_block_offset_continuation():
    """The registry switch honours the canonical convention: None is
    the offset-free trace, an integer continues the stream exactly."""
    with G.x64():
        full = np.asarray(G.gen_block_by_id(0, 7, 3, 128, offset=None))
        head = np.asarray(G.gen_block_by_id(0, 7, 3, 64))
        tail = np.asarray(G.gen_block_by_id(0, 7, 3, 64, offset=64))
    np.testing.assert_array_equal(full, np.concatenate([head, tail]))


def test_stream_and_seam_offsets_validate_bounds():
    with pytest.raises(ValueError, match="span must be >= 1"):
        G.stream_offsets(4, 0)
    with pytest.raises(ValueError, match="span must be >= 1"):
        G.seam_offsets(3, -64, 64)
    with pytest.raises(ValueError, match="n_words"):
        G.seam_offsets(3, 1000, 0)
    with pytest.raises(ValueError, match="span >= n_words"):
        G.seam_offsets(3, 100, 200)
    with pytest.raises(ValueError, match="stream 3"):
        G.stream_offsets(4, 2 ** 62)
    with pytest.raises(ValueError, match="stream"):
        G.seam_offsets(4, 2 ** 62, 64)


# ------------------------------------------------------ captured parity

def test_captured_battery_bitwise_parity(session, capture):
    """ISSUE 8 acceptance: a memory-mapped capture of splitmix64's
    words earns the SAME p-values, bit for bit, as the generator."""
    r_gen = session.submit(_spec("splitmix64")).result()
    r_cap = session.submit(_spec(f"file:{capture}")).result()
    assert r_cap.results == r_gen.results
    assert r_cap.verdict.decision == r_gen.verdict.decision == stitch.PASS


def test_captured_campaign_parity(session, capture):
    """The campaign phase machinery (stream grid + seam check) decides
    captured cells exactly as the generator cells of the same bits."""
    def cspec(src):
        return CampaignSpec("smallcrush", sources=(src,), n_streams=2,
                            seed=7, waves=(SCALE,))
    res_gen = Campaign(session, cspec("splitmix64")).run()
    res_cap = Campaign(session, cspec(f"file:{capture}")).run()
    np.testing.assert_array_equal(res_cap.decisions, res_gen.decisions)
    np.testing.assert_array_equal(res_cap.decided_phase,
                                  res_gen.decided_phase)


def test_captured_bounds_errors_are_typed(tmp_path):
    path = str(tmp_path / "tiny.npy")
    np.save(path, np.arange(8, dtype=np.uint32).reshape(2, 4))
    src = CapturedSource(path)
    np.testing.assert_array_equal(src.block(0, 1, 4, None),
                                  np.arange(4, 8, dtype=np.uint32))
    with pytest.raises(CapturedBitsError, match="stream 0"):
        src.block(0, 0, 5, None)                # word range past shard
    with pytest.raises(CapturedBitsError, match="stream 2"):
        src.block(0, 2, 1, None)                # shard index out of range
    raw = str(tmp_path / "words.u32")
    np.arange(16, dtype="<u4").tofile(raw)
    u32 = CapturedSource(raw, "u32")
    np.testing.assert_array_equal(u32.block(0, 0, 4, 4),
                                  np.arange(4, 8, dtype=np.uint32))
    with pytest.raises(CapturedBitsError, match="stream 1"):
        u32.block(0, 1, 4, None)                # raw u32 = one stream


# ------------------------------------------------------- serve behaviour

def test_captured_resubmission_hits_modified_copy_misses(tmp_path,
                                                         capture):
    """ISSUE 8 acceptance: resubmitting the same captured file (even
    from a copied path) HITS the result cache with zero added
    dispatches; a byte-modified copy under the SAME name MISSES."""
    q = SubmissionQueue(session=PoolSession(),
                        state_dir=str(tmp_path / "state"))
    t1 = q.submit(_spec(f"file:{capture}"))
    q.drain()
    r1 = t1.result()
    base = q.dispatch_rounds
    assert base > 0
    data = open(capture, "rb").read()
    copy_dir = tmp_path / "copy"
    copy_dir.mkdir()
    copy = str(copy_dir / os.path.basename(capture))   # same cap: name
    with open(copy, "wb") as f:
        f.write(data)
    t2 = q.submit(_spec(f"file:{copy}"))
    q.drain()
    assert t2.result().verdict.decision == r1.verdict.decision
    assert q.dispatch_rounds == base            # zero added dispatches
    assert t2.cache_hits == 1
    mod_dir = tmp_path / "mod"
    mod_dir.mkdir()
    mod = str(mod_dir / os.path.basename(capture))     # same cap: name
    tampered = bytearray(data)
    tampered[-1] ^= 0xFF                        # flip one payload byte
    with open(mod, "wb") as f:
        f.write(bytes(tampered))
    t3 = q.submit(_spec(f"file:{mod}"))
    q.drain()
    t3.result()
    assert t3.cache_hits == 0                   # different bits: MISS
    assert q.dispatch_rounds > base


def test_external_generator_survives_daemon_restart(tmp_path):
    """An out-of-repo generator's in-flight work resumes across a serve
    restart PROVIDED the hook re-registers it first; without the
    registration the resume fails loudly with the re-register hint."""
    state = str(tmp_path / "state")
    register_generator("extgen", G.splitmix64_block, counter_based=True)
    try:
        q1 = SubmissionQueue(session=PoolSession(), state_dir=state)
        q1.submit(_spec("extgen"))
        q1.step(flush=True)                     # admit + round 1
        q1.step(flush=True)                     # round 2
        before = q1.dispatch_rounds
        assert 0 < before < 10                  # mid-flight "crash"
    finally:
        unregister_generator("extgen")
    with pytest.raises(KeyError, match="re-registered"):
        _spec("extgen")                         # lost without the hook
    register_generator("extgen", G.splitmix64_block, counter_based=True)
    try:
        q2 = SubmissionQueue(session=PoolSession(), state_dir=state)
        t = q2.submit(_spec("extgen"))
        q2.drain()
        assert t.result().verdict.decision == stitch.PASS
        # only the rounds the first daemon hadn't finished dispatched
        assert before + q2.dispatch_rounds == 10
    finally:
        unregister_generator("extgen")


# ------------------------------------------------- wire-format upgrades

def test_checkpoint_v5_roundtrip_and_v3_v4_upgrade(tmp_path):
    path = str(tmp_path / "ck.ck")
    ck = Checkpoint(np.arange(3, dtype=np.int32),
                    np.ones((1, 3)), np.ones((1, 3)) * 0.5,
                    source_uids=np.asarray([b"gen:splitmix64"]))
    ck.save(path)
    back = Checkpoint.load(path)
    assert back.version == CKPT_VERSION == 5
    assert [u.decode() for u in back.source_uids] == ["gen:splitmix64"]
    leaves = ckpt_io.load_flat(path)
    assert len(leaves) == 10                    # v5 wire layout pin
    # a v4 file (no engine/wealth leaves) loads transparently
    v4 = str(tmp_path / "v4.ck")
    ckpt_io.save(v4, [np.int64(4)] + leaves[1:8])
    mid = Checkpoint.load(v4)
    assert mid.version == 4 and mid.engine == "bonferroni"
    assert mid.log_wealth is None
    np.testing.assert_array_equal(mid.job_idx, back.job_idx)
    # a v3 file (no source identity either) loads transparently
    v3 = str(tmp_path / "v3.ck")
    ckpt_io.save(v3, [np.int64(3)] + leaves[1:7])
    old = Checkpoint.load(v3)
    assert old.version == 3 and old.source_uids is None
    np.testing.assert_array_equal(old.job_idx, back.job_idx)
    with pytest.raises(ValueError, match="leaves"):
        ckpt_io.save(v3, leaves[:6])
        Checkpoint.load(v3)


def test_checkpoint_refuses_recaptured_file(tmp_path):
    """A checkpoint written against one capture refuses to resume
    against a byte-different re-capture of the same path."""
    path = capture_generator("splitmix64", str(tmp_path / "c.npy"),
                             seed=7, n_streams=16, stride=STRIDE)
    ck = str(tmp_path / "run.ck")
    PoolSession().submit(
        _spec(f"file:{path}", checkpoint_path=ck)).result()
    data = bytearray(open(path, "rb").read())
    data[-1] ^= 0xFF
    with open(path, "wb") as f:                 # re-capture, same path
        f.write(bytes(data))
    with pytest.raises(ValueError, match="re-captured"):
        PoolSession().submit(
            _spec(f"file:{path}", checkpoint_path=ck)).result()


def test_campaign_ledger_v2_upgrade_and_recapture_refusal(tmp_path):
    path = capture_generator("splitmix64", str(tmp_path / "c.npy"),
                             seed=7, n_streams=16, stride=STRIDE)
    ledger_path = str(tmp_path / "camp.ck")
    spec = CampaignSpec("smallcrush", sources=(f"file:{path}",),
                        n_streams=2, seed=7, waves=(SCALE,),
                        ledger_path=ledger_path)
    Campaign(PoolSession(), spec).run()
    led = CampaignLedger.load(ledger_path)
    assert led.version == CAMPAIGN_LEDGER_VERSION == 3
    assert led.engine == "bonferroni" and led.continuations == 0
    assert led.source_uids is not None and led.matches(spec)
    # a v1 ledger (no uids leaf) loads transparently and still matches
    # a generator-only campaign of the same grid
    gspec = CampaignSpec("smallcrush", generators=("splitmix64",),
                         n_streams=2, seed=7, waves=(SCALE,))
    v1_path = str(tmp_path / "v1.ck")
    v1 = CampaignLedger.fresh(gspec)
    leaves = (ckpt_io.load_flat(ledger_path))
    ckpt_io.save(v1_path, [
        np.int64(1), np.asarray(v1.gen_ids), np.asarray(v1.streams),
        np.asarray(v1.decisions), np.asarray(v1.decided_phase),
        np.int64(0), np.float64(gspec.alpha),
        np.uint64(gspec.digest())])
    old = CampaignLedger.load(v1_path)
    assert old.version == 1 and old.source_uids is None
    assert old.matches(gspec)
    assert len(leaves) == 12
    # a v2 ledger (uids, but no wealth/engine leaves) also upgrades
    v2_path = str(tmp_path / "v2.ck")
    ckpt_io.save(v2_path, [np.int64(2)] + leaves[1:9])
    mid = CampaignLedger.load(v2_path)
    assert mid.version == 2 and mid.engine == "bonferroni"
    assert mid.log_wealth is None and mid.continuations == 0
    assert mid.matches(spec)
    # re-capture the file: the v2 ledger refuses the new spec
    data = bytearray(open(path, "rb").read())
    data[-1] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(data))
    respec = CampaignSpec("smallcrush", sources=(f"file:{path}",),
                          n_streams=2, seed=7, waves=(SCALE,),
                          ledger_path=ledger_path)
    assert not led.matches(respec)

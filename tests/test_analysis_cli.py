"""Golden-key tests for ``python -m repro.analysis --json`` — the
machine-readable contract the CI static-analysis gate and any
downstream dashboards consume (same discipline as tests/test_cli_json.py
for the battery CLI). Keys are append-only: renaming or dropping one
fails here before any consumer rots."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TOP_KEYS = {"version", "strict", "clean", "files_scanned", "rules",
            "findings", "baselined", "suppressed", "stale_baseline",
            "counts"}
RULE_KEYS = {"code", "name", "summary"}
FINDING_KEYS = {"code", "rule", "path", "line", "col", "message"}
COUNT_KEYS = {"findings", "baselined", "suppressed", "stale_baseline",
              "by_code"}


def _cli(json_path, *args):
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         "--json", json_path, *args],
        env=env, cwd=REPO, capture_output=True, text=True)
    assert os.path.exists(json_path), (
        f"analyzer wrote no json report (exit {p.returncode}):\n"
        f"{p.stdout[-2000:]}\n{p.stderr[-2000:]}")
    with open(json_path) as f:
        return p.returncode, json.load(f)


@pytest.fixture(scope="module")
def strict_report(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("analysis") / "report.json")
    return _cli(path, "--strict")


def test_strict_gate_is_clean(strict_report):
    """ISSUE 6 acceptance: the CI gate exits 0 on the repo tree."""
    code, rep = strict_report
    assert code == 0, rep.get("findings")
    assert rep["clean"] is True
    assert rep["strict"] is True
    assert rep["findings"] == []
    assert rep["stale_baseline"] == []


def test_json_golden_keys(strict_report):
    _, rep = strict_report
    assert set(rep) == TOP_KEYS
    assert rep["version"] == 1
    assert rep["files_scanned"] > 50
    for rule in rep["rules"]:
        assert set(rule) == RULE_KEYS
    for finding in (rep["findings"] + rep["baselined"]
                    + rep["suppressed"]):
        assert set(finding) == FINDING_KEYS
    assert set(rep["counts"]) == COUNT_KEYS


def test_rule_catalog_covers_the_families(strict_report):
    """>= 4 rule families ship, with stable codes."""
    _, rep = strict_report
    codes = [r["code"] for r in rep["rules"]]
    assert codes == sorted(codes)
    families = {c[:4] for c in codes}
    assert {"RPA1", "RPA2", "RPA3", "RPA4", "RPA5"} <= families
    # the load-bearing codes this PR documents must exist by name
    by_code = {r["code"]: r["name"] for r in rep["rules"]}
    assert by_code["RPA101"] == "traced-python-branch"
    assert by_code["RPA201"] == "cache-key-missing-field"
    assert by_code["RPA303"] == "vmem-budget"
    assert by_code["RPA501"] == "unreachable-module"


def test_suppressed_oracle_findings_are_reported(strict_report):
    """Suppressions stay visible in the machine report (not silently
    swallowed): the two ref-oracle RPA501s."""
    _, rep = strict_report
    sup = {(f["code"], f["path"]) for f in rep["suppressed"]}
    assert sup == {
        ("RPA501", "src/repro/kernels/gf2_rank/ref.py"),
        ("RPA501", "src/repro/kernels/histogram/ref.py"),
    }
    assert rep["counts"]["suppressed"] == 2


def test_list_rules_and_exit_codes(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--list-rules"],
        env=env, cwd=REPO, capture_output=True, text=True)
    assert p.returncode == 0
    assert "RPA101" in p.stdout and "RPA501" in p.stdout
    # a bogus root is a usage error, not a crash or a false pass
    p = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--root",
         str(tmp_path)],
        env=env, cwd=REPO, capture_output=True, text=True)
    assert p.returncode == 2

"""Docs-layer gates: docstring coverage on the public core (the local,
stdlib-only twin of the CI `interrogate --fail-under 80` job) and the
README's claims that are cheap to pin (quickstart paths exist, DESIGN
sections it links are real)."""
import ast
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CORE = os.path.join(REPO, "src", "repro", "core")
FAIL_UNDER = 80.0


def _covered(path):
    """(documented, total) over module + public classes + public
    functions/methods (nested defs and ``_private`` names excluded —
    matching the flags the CI interrogate job runs with)."""
    with open(path) as f:
        tree = ast.parse(f.read(), path)
    doc, tot = (1 if ast.get_docstring(tree) else 0), 1
    for node in ast.walk(tree):
        if not isinstance(node, (ast.ClassDef, ast.FunctionDef,
                                 ast.AsyncFunctionDef)):
            continue
        if node.name.startswith("_"):
            continue
        parent_defs = [n for n in ast.walk(tree)
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))
                       and node is not n
                       and any(node is c for c in ast.walk(n))]
        if parent_defs:                 # nested function: skip
            continue
        tot += 1
        doc += 1 if ast.get_docstring(node) else 0
    return doc, tot


def test_core_docstring_coverage():
    """src/repro/core must stay >= 80% documented — the API tour in
    README.md leans on these docstrings being real."""
    doc = tot = 0
    per_file = {}
    for fname in sorted(os.listdir(CORE)):
        if not fname.endswith(".py"):
            continue
        d, t = _covered(os.path.join(CORE, fname))
        per_file[fname] = (d, t)
        doc += d
        tot += t
    cov = 100.0 * doc / tot
    assert cov >= FAIL_UNDER, (
        f"docstring coverage on src/repro/core is {cov:.1f}% "
        f"(< {FAIL_UNDER}%): {per_file}")


@pytest.mark.parametrize("module", ["api.py", "policies.py", "evidence.py"])
def test_core_public_surface_fully_documented(module):
    """The modules README's API tour points at are held to 100%."""
    d, t = _covered(os.path.join(CORE, module))
    assert d == t, f"{module}: {t - d} undocumented public def(s)"


def test_backends_module_documented():
    d, t = _covered(os.path.join(REPO, "src", "repro", "stats",
                                 "backends.py"))
    assert d == t, f"backends.py: {t - d} undocumented public def(s)"


def test_serve_layer_fully_documented():
    """The serving surface (repro/serve + its daemon CLI) is public API
    from day one — held to 100% like api.py/policies.py."""
    serve_dir = os.path.join(REPO, "src", "repro", "serve")
    paths = [os.path.join(serve_dir, f)
             for f in sorted(os.listdir(serve_dir)) if f.endswith(".py")]
    paths.append(os.path.join(REPO, "src", "repro", "launch", "serve.py"))
    for path in paths:
        d, t = _covered(path)
        assert d == t, (f"{os.path.relpath(path, REPO)}: {t - d} "
                        f"undocumented public def(s)")


def test_readme_links_and_paths_exist():
    """README examples/paths/DESIGN sections must not rot."""
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for rel in re.findall(r"`(src/[\w/]+\.py|examples/[\w]+\.py|"
                          r"benchmarks/[\w]+\.py|tests/[\w]+\.py)`",
                          readme):
        assert os.path.exists(os.path.join(REPO, rel)), rel
    with open(os.path.join(REPO, "DESIGN.md")) as f:
        design = f.read()
    for sec in set(re.findall(r"§(\d+)", readme)):
        assert f"## §{sec} " in design, f"README cites missing DESIGN §{sec}"

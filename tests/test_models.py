"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs (brief §f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import decode as dec
from repro.models import lm

B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = lm.forward(params, batch["tokens"], cfg,
                             frames=batch.get("frames"))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    loss, grads = jax.value_and_grad(lm.loss_fn)(params, batch, cfg)
    assert jnp.isfinite(loss)
    gn = sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, cache = dec.prefill(params, batch["tokens"], cfg,
                                max_seq=S + 4, frames=batch.get("frames"))
    assert logits.shape == (B, cfg.padded_vocab)
    lg, cache = dec.decode_step(params, cache, batch["tokens"][:, :1], cfg)
    assert lg.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(lg)))
    assert int(cache["pos"]) == S + 1


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "xlstm-1.3b", "zamba2-1.2b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce full-forward logits (cache
    correctness), for attention, xlstm and hybrid cache types."""
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(2)
    params = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
    full_logits, _ = lm.forward(params, toks, cfg)
    _, cache = dec.prefill(params, toks[:, :8], cfg, max_seq=16)
    errs = []
    for t in range(8, 15):
        lg, cache = dec.decode_step(params, cache, toks[:, t:t + 1], cfg)
        errs.append(float(jnp.max(jnp.abs(
            lg - full_logits[:, t]))))
    assert max(errs) < 2e-2, errs


def test_full_config_param_counts():
    expect = {"granite-moe-1b-a400m": 1.33, "deepseek-v2-236b": 235.7,
              "glm4-9b": 9.4, "gemma2-27b": 27.2, "nemotron-4-340b": 341.0,
              "qwen2-1.5b": 1.54, "chameleon-34b": 34.3,
              "whisper-small": 0.30, "xlstm-1.3b": 2.02,
              "zamba2-1.2b": 1.17}
    for arch, bn in expect.items():
        n = lm.count_params(get_config(arch)) / 1e9
        assert abs(n - bn) / bn < 0.02, (arch, n, bn)

"""Adaptive early stopping: statistical calibration of the sequential
verdict engine, equivalence of early-stopped and full-battery verdicts,
cancellation, and verdict-state checkpoint resume.

The calibration tests are the point of this file (Wartel & Hill: a
parallel test rig's verdicts are only trustworthy if the rig itself is
calibrated): under the null the adaptive verdict's false-FAIL rate must
stay within the binomial CI of the configured alpha, and the round-level
p-values must stay uniform when the adaptive policy reorders execution.
"""
import numpy as np
import pytest

from repro.core import stitch
from repro.core.api import PoolSession, RunSpec
from repro.core.battery import DISCRIMINATION, build_battery, discrimination
from repro.core.policies import get_policy
from repro.core.stitch import FAIL, PASS, UNDECIDED, sequential_verdict

SCALE = 0.125
GOOD = ("splitmix64", "threefry", "pcg32", "xorshift64s", "mwc", "msweyl",
        "lcg64")


@pytest.fixture(scope="module")
def session():
    return PoolSession()


def wilson_ci(k: int, n: int, z: float = 2.576):
    """99% Wilson score interval for a binomial proportion."""
    p = k / n
    denom = 1 + z ** 2 / n
    center = (p + z ** 2 / (2 * n)) / denom
    half = z * np.sqrt(p * (1 - p) / n + z ** 2 / (4 * n ** 2)) / denom
    return center - half, center + half


# ------------------------------------------------------- verdict engine

def test_sequential_verdict_basic():
    n = 10
    v = sequential_verdict({}, n, alpha=0.01)
    assert v.decision == UNDECIDED and not v.decided
    full_null = {i: (0.0, 0.5) for i in range(n)}
    assert sequential_verdict(full_null, n, 0.01).decision == PASS
    bad = dict(full_null)
    bad[3] = (9.0, 1e-12)
    v = sequential_verdict(bad, n, 0.01)
    assert v.decision == FAIL and v.failed_tests == (3,)
    # high tail is rejected too (TestU01's two-sided suspect convention)
    hi = dict(full_null)
    hi[7] = (9.0, 1.0 - 1e-12)
    assert sequential_verdict(hi, n, 0.01).decision == FAIL
    # invalid/missing results don't count as checked
    part = {0: (0.0, 0.5), 1: (float("nan"), float("nan"))}
    v = sequential_verdict(part, n, 0.01)
    assert v.n_checked == 1 and v.decision == UNDECIDED
    with pytest.raises(ValueError):
        sequential_verdict({}, 0, 0.01)


def test_verdict_order_invariant():
    """Stopping at ANY interim look never contradicts the full-battery
    decision — the Bonferroni boundary is fixed per test up front."""
    rng = np.random.default_rng(0)
    n, alpha = 10, 0.05
    for trial in range(200):
        ps = rng.uniform(size=n)
        if trial % 3 == 0:
            ps[rng.integers(n)] = 10.0 ** -rng.uniform(4, 12)
        full = sequential_verdict(
            {i: (0.0, p) for i, p in enumerate(ps)}, n, alpha)
        order = rng.permutation(n)
        interim = {}
        for i in order:
            interim[int(i)] = (0.0, float(ps[i]))
            v = sequential_verdict(interim, n, alpha)
            if v.decision == FAIL:
                break
        assert v.decision == full.decision


def test_engine_false_fail_rate_within_binomial_ci_of_alpha():
    """Calibration headline, engine level: feed the sequential verdict
    engine many synthetic null batteries (uniform p-values) and check the
    false-FAIL rate sits inside the binomial CI around alpha (it is
    guaranteed <= alpha; it must also not collapse to ~0, i.e. the engine
    actually spends its budget)."""
    rng = np.random.default_rng(42)
    n, alpha, m = 10, 0.05, 4000
    fails = 0
    for _ in range(m):
        ps = rng.uniform(size=n)
        v = sequential_verdict({i: (0.0, p) for i, p in enumerate(ps)},
                               n, alpha)
        assert v.decision in (PASS, FAIL)
        fails += v.decision == FAIL
    lo, hi = wilson_ci(fails, m)
    # exact null crossing prob: 1 - (1 - alpha/n)^n, slightly below alpha
    expect = 1.0 - (1.0 - alpha / n) ** n
    assert lo <= alpha, (fails, m, lo, hi)         # not anti-conservative
    assert lo <= expect <= hi, (fails, m, lo, hi)  # and spends the budget


@pytest.mark.slow
def test_null_false_fail_rate_end_to_end(session):
    """Calibration headline, end to end: real batteries on the good
    generators over many seeds/streams. The adaptive verdict's false-FAIL
    rate must stay within the (99%) binomial CI of the configured alpha."""
    alpha, verdicts = 0.05, []
    for seed in range(10):
        spec = RunSpec("smallcrush", GOOD, seed, scale=SCALE,
                       policy="adaptive", alpha=alpha, stop_on_verdict=True)
        res = session.submit(spec).result()
        for g in GOOD:
            v = res.runs[g].verdict
            assert v.decided, (g, seed)
            verdicts.append(v.decision)
    m = len(verdicts)
    fails = verdicts.count(FAIL)
    lo, hi = wilson_ci(fails, m)
    assert lo <= alpha <= max(hi, alpha), (fails, m, lo, hi)
    # the engine must not be wildly anti-conservative on real batteries
    assert fails / m <= alpha + 3 * np.sqrt(alpha * (1 - alpha) / m)


@pytest.mark.slow
def test_round_level_pvalues_uniform_under_adaptive_order(session):
    """Reordering rounds by the adaptive policy must not bias p-values:
    results are bitwise those of any other schedule (deterministic
    streams), and the p-values seen in the EARLY rounds — the ones an
    early-stopped run acts on — look uniform, not tail-inflated."""
    lpt = session.submit(RunSpec("smallcrush", "splitmix64", 3,
                                 scale=SCALE, policy="lpt")).result()
    ada = session.submit(RunSpec("smallcrush", "splitmix64", 3,
                                 scale=SCALE, policy="adaptive")).result()
    assert ada.results == lpt.results            # bitwise order-invariance
    # pool early-round p-values across seeds: first half of the adaptive
    # execution order, which front-loads the discriminating kernels
    entries = build_battery("smallcrush", SCALE)
    plan = get_policy("adaptive").plan_entries(entries, 1)
    early_jobs = [int(j) for j in plan.assignment[:5].ravel() if j >= 0]
    early_p = []
    for seed in range(6):
        res = session.submit(RunSpec("smallcrush", "splitmix64", seed,
                                     scale=SCALE,
                                     policy="adaptive")).result()
        early_p.extend(res.results[j][1] for j in early_jobs)
    early_p = np.asarray(early_p)
    assert 0.25 < early_p.mean() < 0.75
    assert (early_p < 0.5).sum() > len(early_p) * 0.2
    assert ((early_p < 1e-4) | (early_p > 1 - 1e-4)).sum() == 0


# ------------------------------------------------- adaptive plan order

def test_adaptive_plan_front_loads_discriminating_tests():
    entries = build_battery("smallcrush", 1.0)
    plan = get_policy("adaptive").plan_entries(entries, 2)
    order = [int(j) for j in plan.assignment.ravel() if j >= 0]
    assert sorted(order) == list(range(len(entries)))   # complete coverage
    names = [entries[j].kname for j in order]
    # the cheap killer (weight, discrimination 1.0, lowest cost-per-power)
    # must beat every zero/low-power heavyweight to the front
    assert names.index("weight") < names.index("coupon")
    assert names.index("weight") < names.index("poker")
    assert names.index("hamcorr") < names.index("coupon")
    # priority actually is discrimination/cost, descending
    prio = [discrimination(entries[j]) / entries[j].cost for j in order]
    assert all(a >= b - 1e-12 for a, b in zip(prio, prio[1:]))


def test_discrimination_table_covers_all_kernels():
    entries = build_battery("bigcrush", 1.0)
    assert {e.kname for e in entries} <= set(DISCRIMINATION)


# ------------------------------------- equivalence + early-stop savings

@pytest.mark.parametrize("gen", ["randu", "minstd"])
def test_early_stop_matches_full_battery_fewer_rounds(session, gen):
    full = session.submit(RunSpec("smallcrush", gen, 9, scale=SCALE,
                                  policy="adaptive")).result()
    earl = session.submit(RunSpec("smallcrush", gen, 9, scale=SCALE,
                                  policy="adaptive",
                                  stop_on_verdict=True)).result()
    assert full.verdict.decision == FAIL
    assert earl.verdict.decision == FAIL
    assert earl.verdict.failed_tests == full.verdict.failed_tests
    assert earl.rounds_run < full.rounds_run      # strictly fewer
    # the results it did compute are bitwise the full battery's
    for i, sp in earl.results.items():
        assert sp == full.results[i]


def test_multi_gen_failed_generator_drops_out(session):
    spec = RunSpec("smallcrush", ("splitmix64", "randu"), 9, scale=SCALE,
                   policy="adaptive", stop_on_verdict=True)
    run = session.submit(spec)
    for status in run.stream():
        pass
    res = run.result()
    assert res.verdicts["randu"].decision == FAIL
    assert res.verdicts["splitmix64"].decision == PASS
    # randu dropped out mid-run: strictly fewer of its tests executed
    n_randu = sum(np.isfinite(p) for _, p in res.runs["randu"].results.values())
    n_good = sum(np.isfinite(p)
                 for _, p in res.runs["splitmix64"].results.values())
    assert n_randu < n_good == 10


# ------------------------------------------------ cancel + checkpointing

def test_cancel_drops_pending_rounds(session):
    run = session.submit(RunSpec("smallcrush", "splitmix64", 2, scale=SCALE,
                                 policy="adaptive"))
    run.poll()
    pending = run.pending_rounds
    assert pending > 0
    assert run.cancel() == pending
    assert run.pending_rounds == 0 and run.held() == []
    assert run.status()["state"] == "cancelled"
    res = run.result()
    assert res.rounds_run == 1
    assert res.verdict.decision == UNDECIDED     # not enough evidence


def test_checkpoint_resume_mid_verdict_undecided(tmp_path, session):
    """Resume BEFORE the verdict lands: the resumed run continues to the
    same early-stopped FAIL, re-executing nothing it already has."""
    ck = str(tmp_path / "mid.ck")
    spec = RunSpec("smallcrush", "randu", 9, scale=SCALE, policy="adaptive",
                   stop_on_verdict=True, checkpoint_path=ck)
    run1 = session.submit(spec)
    run1.poll()                                   # one round, no verdict yet
    assert run1.verdict().decision == UNDECIDED
    run2 = session.submit(spec)                   # fresh handle, same ckpt
    assert run2.rounds_run == 1                   # verdict state survived
    res = run2.result()
    assert res.verdict.decision == FAIL
    assert res.rounds_run < res.plan_rounds + 1


def test_checkpoint_resume_after_verdict_runs_nothing(tmp_path, session):
    ck = str(tmp_path / "decided.ck")
    spec = RunSpec("smallcrush", "randu", 9, scale=SCALE, policy="adaptive",
                   stop_on_verdict=True, checkpoint_path=ck)
    res1 = session.submit(spec).result()
    assert res1.verdict.decision == FAIL
    run2 = session.submit(spec)
    assert run2.pending_rounds == 0               # nothing re-enqueued
    assert run2.verdict().decision == FAIL
    res2 = run2.result()
    assert res2.rounds_run == res1.rounds_run     # no extra work
    assert res2.results == res1.results


def test_checkpoint_v2_rejects_wrong_generator_count(tmp_path, session):
    ck = str(tmp_path / "v2.ck")
    spec = RunSpec("smallcrush", ("splitmix64", "randu"), 9, scale=SCALE,
                   policy="adaptive", stop_on_verdict=True,
                   checkpoint_path=ck)
    session.submit(spec).poll()
    bad = RunSpec("smallcrush", "splitmix64", 9, scale=SCALE,
                  policy="adaptive", stop_on_verdict=True,
                  checkpoint_path=ck)
    with pytest.raises(ValueError):
        session.submit(bad)


def test_checkpoint_layout_is_uniform_v5(tmp_path, session):
    """Every run — with or without stop_on_verdict — writes the uniform
    job-id-keyed v5 layout (worker-count independent, DESIGN.md §6), and
    verdict state always rides along."""
    from repro.ckpt import io as ckpt_io
    from repro.core.api import CKPT_VERSION, Checkpoint
    ck = str(tmp_path / "v5.ck")
    spec = RunSpec("smallcrush", "splitmix64", 11, scale=SCALE,
                   policy="adaptive", checkpoint_path=ck)
    session.submit(spec).result()
    leaves = ckpt_io.load_flat(ck)
    assert len(leaves) == 10 and int(leaves[0]) == CKPT_VERSION
    saved = Checkpoint.load(ck)
    assert saved.n_generators == 1
    assert list(saved.decisions) == [1]          # PASS rode along


# ------------------------------------------------------------- alpha knob

def test_runspec_validates_alpha():
    with pytest.raises(ValueError):
        RunSpec("smallcrush", "splitmix64", 1, alpha=0.0)
    with pytest.raises(ValueError):
        RunSpec("smallcrush", "splitmix64", 1, alpha=1.5)


def test_stricter_alpha_is_harder_to_fail():
    results = {i: (0.0, 0.5) for i in range(9)}
    results[9] = (5.0, 2e-4)
    assert sequential_verdict(results, 10, alpha=0.05).decision == FAIL
    assert sequential_verdict(results, 10, alpha=0.001).decision == PASS

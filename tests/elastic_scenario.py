"""Multi-width elastic re-meshing scenario, run as a SUBPROCESS by
tests/test_elastic.py: the resize invariants need a pool wider than one
device, and the forced host-device count must be set before jax imports,
which the parent test process (already holding an initialized jax) cannot
do for itself.

Covers, on an 8-wide forced-device pool:
  * fixed W=8 vs resized 8 -> 3 -> 8 runs stitching bitwise-identical
    p-values for single-generator, fan-out and over_decompose specs;
  * compile-cache trace counts showing only the new width recompiles;
  * the W=8 -> W=4 checkpoint-resume regression (job-id-keyed v3 layout);
  * the v2 -> v3 checkpoint upgrade path across a width change.

Prints one JSON dict on the last stdout line; the pytest side asserts.
Usage: python tests/elastic_scenario.py <tmpdir>
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json                                            # noqa: E402
import sys                                             # noqa: E402

import numpy as np                                     # noqa: E402

from repro.ckpt import io as ckpt_io                   # noqa: E402
from repro.core.api import (                           # noqa: E402
    Checkpoint, PoolSession, RunSpec)
from repro.core.policies import OverDecomposePolicy    # noqa: E402

SCALE = 0.0625
tmp = sys.argv[1]
out = {}


def drive_resized(session, spec, shrink_to=3):
    """One run with the pool bouncing 8 -> shrink_to -> 8 mid-battery."""
    handle = session.submit(spec)
    handle.poll()
    session.resize(shrink_to)
    handle.poll()
    session.grow(8 - shrink_to)
    return handle.result()


def keyed(res):
    """{generator: {job: (stat, p)}} for single- and multi-gen results."""
    runs = getattr(res, "runs", None)
    if runs is None:
        return {"_": res.results}
    return {g: r.results for g, r in runs.items()}


fixed = PoolSession(n_workers=8)
elastic = PoolSession(n_workers=8)

# --- 1. single generator: bitwise stitched p-values + trace accounting
spec1 = RunSpec("smallcrush", "splitmix64", 7, scale=SCALE)
out["single_bitwise"] = (keyed(fixed.submit(spec1).result())
                         == keyed(drive_resized(elastic, spec1)))
out["single_trace_widths"] = sorted(
    [k[2], v] for k, v in elastic.trace_counts.items())

# --- 2. multi-generator fan-out (vmapped gen_ids axis) across a resize
spec2 = RunSpec("smallcrush", ("splitmix64", "randu"), 7, scale=SCALE)
out["fanout_bitwise"] = (keyed(fixed.submit(spec2).result())
                         == keyed(drive_resized(elastic, spec2)))

# --- 3. over-decomposed sub-streams survive the resize (the cut is a
# function of the battery, never of the width)
od = OverDecomposePolicy(threshold=0.05, max_parts=4)
spec3 = RunSpec("smallcrush", "splitmix64", 7, scale=SCALE, policy=od)
out["overdec_bitwise"] = (keyed(fixed.submit(spec3).result())
                          == keyed(drive_resized(elastic, spec3)))

# --- 4. regression: checkpoint written at W=8 resumes on a W=4 pool
ck = os.path.join(tmp, "w8.ck")
spec_ck = RunSpec("smallcrush", "splitmix64", 7, scale=SCALE,
                  checkpoint_path=ck)
res1 = fixed.submit(spec_ck).result()
Checkpoint.load(ck).drop([2, 8]).save(ck)          # two "node failures"
fixed.resize(4)
run2 = fixed.submit(spec_ck)
status = run2.status()
out["resume_missing"] = status["jobs_total"] - status["jobs_done"]
res2 = run2.result()
out["resume_bitwise"] = res2.results == res1.results
out["resume_rounds"] = res2.rounds_run
out["resume_ckpt_version"] = int(ckpt_io.load_flat(ck)[0])

# --- 5. v2 -> v3 upgrade across the width change: hand-write the legacy
# 5-leaf layout (UNDECIDED verdict state, partial results), resume at
# W=4, and confirm the next save upgrades the file to v3
ck2 = os.path.join(tmp, "v2.ck")
partial = Checkpoint.load(ck).drop([1, 4])
ckpt_io.save(ck2, [partial.job_idx, partial.stats, partial.ps,
                   np.zeros(1, np.int8), np.int64(2)])
spec_v2 = RunSpec("smallcrush", "splitmix64", 7, scale=SCALE,
                  checkpoint_path=ck2)
res3 = fixed.submit(spec_v2).result()
out["v2_upgrade_bitwise"] = res3.results == res1.results
out["v2_upgraded_leaves"] = len(ckpt_io.load_flat(ck2))

print(json.dumps(out))

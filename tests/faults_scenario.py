"""Multi-worker fault-domain scenario, run as a SUBPROCESS by
tests/test_faults.py: quarantine and ``lose_worker`` need a pool wider
than one device, and the forced host-device count must be set before
jax imports, which the parent test process (already holding an
initialized jax) cannot do for itself.

Covers, on a 4-wide forced-device pool:
  * ``lose_worker`` at round 0 shrinking the pool to 3 mid-battery,
    with stitched p-values bitwise identical to the clean W=4 run;
  * a persistently flaky slot (evict slot 1 every round) walked down by
    the quarantine machinery 4 -> 3 -> 2 -> 1 until the rule can no
    longer match, completing with bitwise-identical p-values — the
    headline "any plan leaving >= 1 healthy worker degrades, never
    corrupts" invariant;
  * the degraded daemon: a ``SubmissionQueue`` whose session was
    quarantined down to one slot keeps serving (ticket DONE, parity)
    and reports ``status == "degraded"`` in ``stats()``.

Prints one JSON dict on the last stdout line; the pytest side asserts.
Usage: python tests/faults_scenario.py <tmpdir>
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json                                            # noqa: E402
import sys                                             # noqa: E402

from repro.core.api import PoolSession, RunSpec        # noqa: E402
from repro.core.faults import FaultPlan, FaultRule     # noqa: E402
from repro.core.policies import RetryPolicy            # noqa: E402
from repro.serve.queue import DONE, SubmissionQueue    # noqa: E402

SCALE = 0.0625
tmp = sys.argv[1]
out = {}

session = PoolSession()
assert session.n_workers == 4, session.n_workers


def spec_for(plan=None, retry=None, policy="lpt"):
    return RunSpec("smallcrush", "splitmix64", 7, scale=SCALE,
                   retry=retry or RetryPolicy(), policy=policy,
                   inject=plan)


clean = session.submit(spec_for()).result()
# roundrobin keeps every slot busy on consecutive rounds, so a
# persistently flaky slot actually accumulates the quarantine streak
# (LPT idles narrow slots late in the battery); parity must hold across
# policies anyway, but the baseline matches the policy under test
clean_rr = session.submit(spec_for(policy="roundrobin")).result()
assert clean_rr.results == clean.results

# -- lose_worker: width drops 4 -> 3 after round 0 ------------------------
lose = FaultPlan(rules=(FaultRule("lose_worker", round=0),))
h = session.submit(spec_for(lose))
res = h.result()
out["lose_worker_bitwise"] = res.results == clean.results
out["lose_worker_final_w"] = session.n_workers
out["lose_worker_events"] = [e.kind for e in h.fault_events]

# -- quarantine: slot 1 evicts every round; pool walks down to W=1 --------
session.resize(4)
flaky = FaultPlan(rules=(FaultRule("evict", slot=1),))
h = session.submit(spec_for(
    flaky, RetryPolicy(max_retries=10, quarantine_after=2),
    policy="roundrobin"))
res = h.result()
out["quarantine_bitwise"] = res.results == clean.results
out["quarantine_verdict"] = res.verdict.decision == clean.verdict.decision
out["quarantines"] = h.quarantines
out["final_workers"] = session.n_workers
out["quarantine_retries"] = res.retries

# -- degraded daemon: quarantined-to-one-slot queue keeps serving ---------
qsession = PoolSession()
qsession.resize(4)
queue = SubmissionQueue(qsession, state_dir=os.path.join(tmp, "serve"),
                        inject=flaky)
t = queue.submit(spec_for(
    retry=RetryPolicy(max_retries=10, quarantine_after=2),
    policy="roundrobin"))
queue.drain()
stats = queue.stats()
out["serve_state"] = t.state == DONE
out["serve_bitwise"] = t.result().results == clean.results
out["serve_status"] = stats["status"]
out["serve_workers"] = stats["workers"]

print(json.dumps(out))

"""Property tests for the pool scheduler (the paper's batch model)."""
import math

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import make_plan, replan


@given(k=st.integers(1, 300), w=st.integers(1, 128))
@settings(max_examples=60, deadline=None)
def test_roundrobin_matches_paper_batch_model(k, w):
    """ceil(K/W) batches — the paper's §11 performance model."""
    plan = make_plan([1.0] * k, w, "roundrobin")
    assert plan.rounds == math.ceil(k / w)


@given(k=st.integers(1, 200), w=st.integers(1, 64),
       seed=st.integers(0, 10))
@settings(max_examples=60, deadline=None)
def test_every_test_scheduled_exactly_once(k, w, seed):
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.1, 10.0, k)
    for mode in ("roundrobin", "lpt"):
        plan = make_plan(costs, w, mode)
        sched = sorted(int(i) for i in plan.assignment.ravel() if i >= 0)
        assert sched == list(range(k))


@given(k=st.integers(2, 150), w=st.integers(2, 48),
       seed=st.integers(0, 20))
@settings(max_examples=60, deadline=None)
def test_lpt_never_worse_than_roundrobin(k, w, seed):
    rng = np.random.default_rng(seed)
    costs = rng.lognormal(0, 1.5, k)        # skewed, like TestU01's tests
    rr = make_plan(costs, w, "roundrobin")
    lpt = make_plan(costs, w, "lpt")
    assert lpt.est_makespan <= rr.est_makespan + 1e-9
    # LPT's classic bound: makespan <= (4/3 - 1/3W) * OPT >= ideal
    assert lpt.est_makespan >= lpt.est_ideal - 1e-9


def test_paper_numbers_106_tests():
    """The paper's concrete claim: 106 tests on 40 cores -> 3 batches;
    70 -> 2; 90 -> still 2 (no improvement)."""
    for w, batches in ((40, 3), (70, 2), (90, 2)):
        plan = make_plan([1.0] * 106, w, "roundrobin")
        assert plan.rounds == batches


@given(w=st.integers(1, 32),
       missing=st.sets(st.integers(0, 49), min_size=1, max_size=20))
@settings(max_examples=40, deadline=None)
def test_replan_covers_exactly_missing(w, missing):
    plan = replan(sorted(missing), [1.0] * 50, w)
    covered = sorted(int(i) for i in plan.assignment.ravel() if i >= 0)
    assert covered == sorted(missing)

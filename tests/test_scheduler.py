"""Property tests for the pool scheduler (the paper's batch model)."""
import math

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import make_plan, replan


@given(k=st.integers(1, 300), w=st.integers(1, 128))
@settings(max_examples=60, deadline=None)
def test_roundrobin_matches_paper_batch_model(k, w):
    """ceil(K/W) batches — the paper's §11 performance model."""
    plan = make_plan([1.0] * k, w, "roundrobin")
    assert plan.rounds == math.ceil(k / w)


@given(k=st.integers(1, 200), w=st.integers(1, 64),
       seed=st.integers(0, 10))
@settings(max_examples=60, deadline=None)
def test_every_test_scheduled_exactly_once(k, w, seed):
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.1, 10.0, k)
    for mode in ("roundrobin", "lpt"):
        plan = make_plan(costs, w, mode)
        sched = sorted(int(i) for i in plan.assignment.ravel() if i >= 0)
        assert sched == list(range(k))


@given(k=st.integers(2, 150), w=st.integers(2, 48),
       seed=st.integers(0, 20))
@settings(max_examples=60, deadline=None)
def test_lpt_never_worse_than_roundrobin(k, w, seed):
    rng = np.random.default_rng(seed)
    costs = rng.lognormal(0, 1.5, k)        # skewed, like TestU01's tests
    rr = make_plan(costs, w, "roundrobin")
    lpt = make_plan(costs, w, "lpt")
    assert lpt.est_makespan <= rr.est_makespan + 1e-9
    # LPT's classic bound: makespan <= (4/3 - 1/3W) * OPT >= ideal
    assert lpt.est_makespan >= lpt.est_ideal - 1e-9


def test_paper_numbers_106_tests():
    """The paper's concrete claim: 106 tests on 40 cores -> 3 batches;
    70 -> 2; 90 -> still 2 (no improvement)."""
    for w, batches in ((40, 3), (70, 2), (90, 2)):
        plan = make_plan([1.0] * 106, w, "roundrobin")
        assert plan.rounds == batches


@given(w=st.integers(1, 32),
       missing=st.sets(st.integers(0, 49), min_size=1, max_size=20))
@settings(max_examples=40, deadline=None)
def test_replan_covers_exactly_missing(w, missing):
    plan = replan(sorted(missing), [1.0] * 50, w)
    covered = sorted(int(i) for i in plan.assignment.ravel() if i >= 0)
    assert covered == sorted(missing)


# ----------------------------------------------------------- policy registry

def test_make_plan_accepts_policy_instances():
    from repro.core.policies import LPTPolicy
    costs = [3.0, 1.0, 2.0]
    assert (make_plan(costs, 2, LPTPolicy()).assignment
            == make_plan(costs, 2, "lpt").assignment).all()


def test_make_plan_unknown_mode_raises():
    import pytest
    with pytest.raises(ValueError):
        make_plan([1.0], 1, "fifo")


@given(k=st.integers(2, 60), w=st.integers(2, 16), seed=st.integers(0, 10))
@settings(max_examples=30, deadline=None)
def test_over_decompose_invariants(k, w, seed):
    """Decomposition shrinks the largest job, never adds cost, covers every
    test exactly once per part, and is independent of the worker count
    (checkpoint job indices must survive elastic re-meshing). Note the
    round-synchronous makespan estimate is NOT guaranteed monotone under
    splitting — LPT packing anomalies are real — so that is not asserted."""
    from repro.core.battery import TestEntry
    from repro.core.policies import OverDecomposePolicy

    rng = np.random.default_rng(seed)
    costs = rng.lognormal(0, 1.5, k)
    # synthetic entries: cost-only jobs the policy can split evenly
    entries = [TestEntry(i, f"t{i}", None, max(int(c * 1000), 8), float(c),
                         kname="weight",
                         params=(("n", max(int(c * 1000), 8)),))
               for i, c in enumerate(costs)]
    policy = OverDecomposePolicy(max_parts=8)
    jobs = policy.decompose(entries, w)
    if jobs is None:                     # nothing heavy enough to split
        return
    assert max(j.cost for j in jobs) <= max(e.cost for e in entries) + 1e-9
    assert sum(j.cost for j in jobs) <= sum(e.cost for e in entries) + 1e-6
    # every original test is covered by its group exactly once per part
    by_group = {}
    for j in jobs:
        by_group.setdefault(j.group, []).append(j.part)
    assert sorted(by_group) == list(range(k))
    for g, parts in by_group.items():
        assert sorted(parts) == list(range(len(parts)))
    # job table is a pure function of the battery, not the mesh width
    other = policy.decompose(entries, w + 7)
    assert [(j.index, j.group, j.part, j.cost) for j in jobs] == \
           [(j.index, j.group, j.part, j.cost) for j in other]

"""Elastic pool re-meshing: `session.resize()` invariants, the
worker-count-independent checkpoint v3 layout (with v1/v2 upgrade), and
the empty-residual guards.

The multi-width assertions run a subprocess scenario
(tests/elastic_scenario.py) because the forced host-device count must be
set before jax initializes; everything it checks is summarized into one
JSON dict the tests here assert on."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.ckpt import io as ckpt_io
from repro.core.api import Checkpoint, PoolSession, RunSpec
from repro.core.battery import build_battery, max_words
from repro.core.policies import OverDecomposePolicy
from repro.core.pool import _job_fn, stream_table
from repro.core.scheduler import replan

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# -------------------------------------------------- empty-residual guards

def test_replan_of_nothing_returns_empty_plan():
    """All jobs done + a resize-triggered replan must complete, not raise
    ``ValueError: max() arg is an empty sequence``."""
    plan = replan([], [1.0] * 10, 4)
    assert plan.rounds == 0
    assert plan.assignment.shape == (0, 4)
    assert plan.est_makespan == 0.0
    entries = build_battery("smallcrush", 0.125)
    for mode in ("roundrobin", "lpt", "adaptive", "over_decompose"):
        sub = replan([], [e.cost for e in entries], 3, mode,
                     entries=entries)
        assert sub.rounds == 0 and sub.assignment.shape == (0, 3)


def test_empty_residual_tables_do_not_raise():
    assert stream_table([]).shape == (0,)
    assert max_words([]) == 0
    assert OverDecomposePolicy().decompose([], 8) is None


# ------------------------------------------------------- resize validation

def test_resize_validates_width():
    session = PoolSession()
    assert session.resize(session.n_workers) == session.n_workers  # no-op
    with pytest.raises(ValueError):
        session.resize(0)
    with pytest.raises(RuntimeError, match="XLA_FLAGS"):
        session.resize(len(jax.devices()) + 7)
    # a failed resize leaves the session usable at its old width
    assert session.n_workers >= 1
    assert session.submit(
        RunSpec("smallcrush", "splitmix64", 3, scale=0.0625)
    ).result().rounds_run > 0


def test_grow_shrink_sugar():
    session = PoolSession()
    w = session.n_workers
    with pytest.raises((ValueError, RuntimeError)):
        session.shrink(w)                       # to zero
    assert session.n_workers == w


# ----------------------------------------------------- idle-slot gating

def test_idle_slot_generation_is_gated():
    """Padded rounds must not pay generator cost for empty slots: the
    idle branch is a zero-length sentinel (the lax.cond returns (0, nan)
    before any bit block exists), and real jobs are untouched."""
    entries = build_battery("smallcrush", 0.0625)
    job = _job_fn(entries)
    with jax.experimental.enable_x64():
        jaxpr = str(jax.make_jaxpr(job)(
            np.int32(-1), np.int32(0), np.int32(0)))
        assert "cond" in jaxpr                  # generation is branched
        stat, p = jax.jit(job)(np.int32(-1), np.int32(0), np.int32(0))
        assert float(stat) == 0.0 and np.isnan(float(p))
        stat0, p0 = jax.jit(job)(np.int32(0), np.int32(7), np.int32(0))
        assert np.isfinite(float(stat0)) and 0.0 <= float(p0) <= 1.0


# --------------------------------------------------- checkpoint v3 layout

def _toy_ckpt():
    idx = np.arange(4, dtype=np.int32)
    st = np.arange(4, dtype=np.float64)[None, :] + 1.0
    pv = np.full((1, 4), 0.5)
    return idx, st, pv


def test_checkpoint_v5_roundtrip_and_drop(tmp_path):
    path = str(tmp_path / "v5.ck")
    idx, st, pv = _toy_ckpt()
    Checkpoint(idx, st, pv, np.array([1], np.int8), rounds_run=3,
               alpha=0.05).save(path)
    ck = Checkpoint.load(path)
    assert ck.version == 5 and ck.rounds_run == 3 and ck.alpha == 0.05
    assert ck.engine == "bonferroni" and ck.log_wealth is None
    assert ck.n_generators == 1
    np.testing.assert_array_equal(ck.job_idx, idx)
    np.testing.assert_array_equal(ck.stats, st)
    assert list(ck.decisions) == [1]
    assert ck.results() == [{i: (float(st[0, i]), 0.5) for i in range(4)}]
    dropped = ck.drop([1, 2])
    assert list(dropped.job_idx) == [0, 3]
    assert dropped.stats.shape == (1, 2)
    assert dropped.decisions is None            # verdict state discarded


def test_checkpoint_v1_v2_load_and_upgrade(tmp_path):
    idx, st, pv = _toy_ckpt()
    p1 = str(tmp_path / "v1.ck")
    ckpt_io.save(p1, [idx, st[0], pv[0]])       # classic flat single-gen
    c1 = Checkpoint.load(p1)
    assert c1.version == 1 and c1.decisions is None
    assert c1.stats.shape == (1, 4)
    p2 = str(tmp_path / "v2.ck")
    ckpt_io.save(p2, [idx, st, pv, np.array([2], np.int8), np.int64(5)])
    c2 = Checkpoint.load(p2)
    assert c2.version == 2 and c2.rounds_run == 5
    assert c2.alpha is None                     # v2 never recorded alpha
    assert list(c2.decisions) == [2]
    c2.save(p2)                                 # upgrade on next save
    assert Checkpoint.load(p2).version == 5
    assert len(ckpt_io.load_flat(p2)) == 10


def test_non_adaptive_resume_ignores_alpha_change(tmp_path):
    """v3 always saves verdict decisions, but they are binding only for
    ``stop_on_verdict`` runs — a plain run resumed under a different
    alpha must resume cleanly, not fail the verdict cross-check (alpha
    never affected its execution)."""
    ck = str(tmp_path / "alpha.ck")
    session = PoolSession()
    res1 = session.submit(RunSpec("smallcrush", "splitmix64", 3,
                                  scale=0.125,
                                  checkpoint_path=ck)).result()
    res2 = session.submit(RunSpec("smallcrush", "splitmix64", 3,
                                  scale=0.125, checkpoint_path=ck,
                                  alpha=0.9)).result()
    assert res2.rounds_run == 0
    assert res2.results == res1.results


def test_adaptive_resume_of_plain_checkpoint_any_alpha(tmp_path):
    """A plain run's checkpoint resumed with ``stop_on_verdict`` under a
    DIFFERENT alpha must recompute verdicts fresh, not fail the binding
    cross-check — v3 records which alpha the saved decisions were
    computed under, and a mismatch makes them advisory."""
    ck = str(tmp_path / "plain.ck")
    session = PoolSession()
    res1 = session.submit(RunSpec("smallcrush", "splitmix64", 3,
                                  scale=0.125,
                                  checkpoint_path=ck)).result()
    res2 = session.submit(RunSpec("smallcrush", "splitmix64", 3,
                                  scale=0.125, checkpoint_path=ck,
                                  stop_on_verdict=True,
                                  alpha=0.9)).result()
    assert res2.rounds_run == 0                 # nothing re-executed
    assert res2.results == res1.results
    assert res2.verdict.decided                 # recomputed under 0.9


def test_checkpoint_rejects_unknown_layouts(tmp_path):
    idx, st, pv = _toy_ckpt()
    bad_ver = str(tmp_path / "bad_ver.ck")
    ckpt_io.save(bad_ver, [np.int64(9), idx, st, pv,
                           np.zeros(0, np.int8), np.int64(0),
                           np.float64(0.01)])
    with pytest.raises(ValueError, match="version"):
        Checkpoint.load(bad_ver)
    bad_len = str(tmp_path / "bad_len.ck")
    ckpt_io.save(bad_len, [idx, st])
    with pytest.raises(ValueError, match="leaves"):
        Checkpoint.load(bad_len)


# -------------------------------------------- multi-width scenario (W=8)

@pytest.fixture(scope="module")
def scenario(tmp_path_factory):
    """Run the 8-device subprocess scenario once; share its JSON verdict."""
    td = tmp_path_factory.mktemp("elastic")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)                  # the scenario forces its own
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "elastic_scenario.py"),
         str(td)],
        env=env, capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stdout[-4000:] + proc.stderr[-4000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_resize_bitwise_single_generator(scenario):
    assert scenario["single_bitwise"]


def test_resize_bitwise_fanout(scenario):
    assert scenario["fanout_bitwise"]


def test_resize_bitwise_over_decompose(scenario):
    assert scenario["overdec_bitwise"]


def test_resize_recompiles_only_new_width(scenario):
    """8 -> 3 -> 8: the 3-wide program traces once; growing back to 8 is
    a compile-cache hit, so width 8 stays at one trace."""
    assert scenario["single_trace_widths"] == [[3, 1], [8, 1]]


def test_w8_checkpoint_resumes_on_w4(scenario):
    """THE regression: a checkpoint saved on an 8-wide mesh, with results
    knocked out, resumes on a 4-wide mesh — only the missing jobs rerun,
    and the stitched results reconcile bitwise."""
    assert scenario["resume_missing"] == 2
    assert scenario["resume_rounds"] == 1       # ceil(2 jobs / 4 workers)
    assert scenario["resume_bitwise"]
    assert scenario["resume_ckpt_version"] == 5


def test_v2_checkpoint_upgrades_across_widths(scenario):
    assert scenario["v2_upgrade_bitwise"]
    assert scenario["v2_upgraded_leaves"] == 10

"""BAD: unpinned integer reduction in a Pallas kernel body — under
ambient x64 the accumulator promotes to int64 (the gf2_rank bug)."""
import jax.numpy as jnp


def _popcount_kernel(rows_ref, out_ref):
    rows = rows_ref[...]
    out_ref[...] = jnp.sum(rows & jnp.uint32(1), axis=1)

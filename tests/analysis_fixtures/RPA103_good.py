"""GOOD: locals may accumulate freely inside a traced function."""
import jax
import jax.numpy as jnp


@jax.jit
def stacked(x):
    parts = []
    for i in range(3):  # static python loop: unrolled at trace time
        parts.append(x + i)
    return jnp.stack(parts)

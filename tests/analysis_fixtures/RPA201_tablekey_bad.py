"""BAD: the session cache key drops a field the table key depends on —
two sessions with different compiled tables would alias."""


class Session:
    def cache_key(self, spec):
        return (spec.battery,)

    def _table_key(self, spec):
        return (spec.battery, spec.backend)

    def _compiled(self, spec):
        return compile_table(spec.battery, spec.backend)


def compile_table(battery, backend):
    return (battery, backend)

"""BAD: `assert` on a traced value inside a jitted function."""
import jax
import jax.numpy as jnp


@jax.jit
def checked_total(x):
    total = jnp.sum(x.astype(jnp.float32))
    assert total >= 0.0, "negative mass"
    return total

"""BAD: _compiled reads spec.backend but the cache key omits it —
the PR 4 resolved-backend bug class."""


class Session:
    def __init__(self):
        self._cache = {}

    def cache_key(self, spec):
        return (spec.battery, float(spec.scale))

    def _compiled(self, spec):
        key = self.cache_key(spec)
        if key not in self._cache:
            self._cache[key] = build(spec.battery, spec.scale,
                                     backend=spec.backend)
        return self._cache[key]


def build(battery, scale, backend):
    return (battery, scale, backend)

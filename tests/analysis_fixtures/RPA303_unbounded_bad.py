"""BAD: a symbolic block dimension with no static bound annotation."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 256


def _count_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def counts(x, k):
    return pl.pallas_call(
        _count_kernel,
        grid=(x.shape[0] // CHUNK,),
        in_specs=[pl.BlockSpec((CHUNK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((k,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
    )(x)

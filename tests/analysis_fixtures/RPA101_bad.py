"""BAD: Python `if` on a traced value inside a jitted function."""
import jax
import jax.numpy as jnp


@jax.jit
def clipped_mean(x):
    m = jnp.mean(x)
    if m > 0.0:
        return m
    return -m

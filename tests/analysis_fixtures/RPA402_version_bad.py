"""BAD: a serialized version constant the reader never checks."""
import numpy as np

from repro.ckpt import io

SNAP_VERSION = 2


class Snapshot:
    def __init__(self, done=0):
        self.done = done

    def save(self, path):
        io.save(path, [np.int64(SNAP_VERSION), np.int64(self.done)])

    @classmethod
    def load(cls, path):
        leaves = io.load_flat(path)
        if len(leaves) != 2:
            raise ValueError("unknown snapshot layout")
        return cls(int(leaves[1]))

"""GOOD (by suppression): an intentional trace-time concretization.

The float() below is deliberate — the operand is a compile-time
constant under this fixture's contract — and carries the analyzer's
inline suppression, so the file reports no findings.
"""
import jax
import jax.numpy as jnp


@jax.jit
def baked(x):
    c = float(jnp.pi * jnp.asarray(2.0))  # repro: noqa RPA102
    return x * c

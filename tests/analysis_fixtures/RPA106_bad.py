"""BAD: fault-injection API called inside a structurally-traced
function — the perturbation would bake into the compile cache at trace
time instead of firing per round on the host (fires RPA106)."""
import jax

from repro.core.faults import FaultInjector


@jax.jit
def round_fn(row, arrays, plan, round_idx):
    injector = FaultInjector(plan)
    events, resize_to = injector.apply_round(round_idx, row, arrays)
    return arrays

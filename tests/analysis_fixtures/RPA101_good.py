"""GOOD: branching on static shape info and via lax primitives."""
import jax
import jax.numpy as jnp


@jax.jit
def folded(x):
    if x.shape[0] > 4:  # static: shapes are known at trace time
        x = x[:4]
    m = jnp.mean(x)
    return jnp.where(m > 0.0, m, -m)

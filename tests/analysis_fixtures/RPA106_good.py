"""GOOD: fault injection stays at the host-side runner boundary — the
traced round function is pure, and the injector perturbs the already-
fetched host arrays after the dispatch returns (no RPA106)."""
import jax
import jax.numpy as jnp

from repro.core.faults import FaultInjector


@jax.jit
def round_fn(row):
    return jnp.sqrt(row)


def dispatch(plan, round_idx, row, arrays):
    out = round_fn(row)
    injector = FaultInjector(plan)
    events, resize_to = injector.apply_round(round_idx, row, arrays)
    return out, events, resize_to

"""BAD: an accelerated kernel family with no reference fallback."""


def gap_ref(bits):
    return bits


def foo_fast(bits):
    return bits


KERNELS = {"gap": gap_ref}

for _k, _fn in KERNELS.items():
    register(_k, "reference", _fn)

register("foo", "accelerated", foo_fast)


def register(name, backend, fn):
    pass

"""GOOD: every field compiled-program construction reads is keyed."""


class Session:
    def __init__(self):
        self._cache = {}

    def cache_key(self, spec):
        resolved = resolve(spec.backend)
        return (spec.battery, float(spec.scale), resolved)

    def _compiled(self, spec):
        key = self.cache_key(spec)
        if key not in self._cache:
            self._cache[key] = build(spec.battery, spec.scale,
                                     backend=resolve(spec.backend))
        return self._cache[key]


def resolve(backend):
    return backend


def build(battery, scale, backend):
    return (battery, scale, backend)

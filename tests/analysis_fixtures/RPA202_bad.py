"""BAD: a RunSpec field is neither keyed nor classified runtime-arg."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RunSpec:
    battery: str
    fancy_mode: str = "off"


class Session:
    def cache_key(self, spec):
        return (spec.battery,)

    def _compiled(self, spec):
        return compile_battery(spec.battery)


def compile_battery(battery):
    return battery

"""GOOD: every registration declares counter_based=; the offset set is
read from the live registry instead of a static tuple."""
from repro.rng.sources import counter_based_names, register_generator


def ext_block(seed, stream, n, offset=None):
    return (seed, stream, n, offset)


def mwcish_block(seed, stream, n):
    return (seed, stream, n)


register_generator("ext", ext_block, counter_based=True)
register_generator("mwcish", mwcish_block, counter_based=False)

OFFSETABLE = counter_based_names()

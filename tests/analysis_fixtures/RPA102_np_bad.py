"""BAD: host numpy called on a traced value inside traced code."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def host_round_trip(x):
    y = jnp.cumsum(x)
    return np.asarray(y)

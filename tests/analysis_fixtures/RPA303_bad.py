"""BAD: block working set far over the VMEM budget."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 2048


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def big_copy(x):
    return pl.pallas_call(
        _copy_kernel,
        grid=(x.shape[0] // TILE,),
        in_specs=[pl.BlockSpec((TILE, TILE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE, TILE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
    )(x)

"""An unreachable module with no quarantine annotation."""

LEFTOVER = 1

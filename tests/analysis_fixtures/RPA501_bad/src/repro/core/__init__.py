"""Battery-system root for the reachability fixture."""

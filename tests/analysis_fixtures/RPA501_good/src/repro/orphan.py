# repro: quarantine -- dead fixture module, kept on purpose
"""An unreachable module, properly annotated."""

LEFTOVER = 1

"""BAD: a jitted function appends to module state at trace time."""
import jax
import jax.numpy as jnp

_TRACE_LOG = []


@jax.jit
def logged_sum(x):
    _TRACE_LOG.append(x.shape)
    return jnp.sum(x.astype(jnp.float32))

"""GOOD: writer layout accepted, version checked, legacy upgraded."""
import numpy as np

from repro.ckpt import io

SNAP_VERSION = 2


class Snapshot:
    def __init__(self, done=0):
        self.done = done

    def save(self, path):
        io.save(path, [np.int64(SNAP_VERSION), np.int64(self.done)])

    @classmethod
    def load(cls, path):
        leaves = io.load_flat(path)
        if len(leaves) == 1:  # v1: bare counter
            return cls(int(leaves[0]))
        if len(leaves) != 2:
            raise ValueError("unknown snapshot layout")
        ver = int(leaves[0])
        if ver != SNAP_VERSION:
            raise ValueError(f"snapshot version {ver}")
        return cls(int(leaves[1]))

"""GOOD: COUNTER_BASED exactly matches the offset-taking signatures."""


def a_block(seed, stream, n, offset=0):
    return (seed, stream, n, offset)


def m_block(seed, stream, n):
    return (seed, stream, n)


GENERATORS = {"a": a_block, "m": m_block}
COUNTER_BASED = ("a",)

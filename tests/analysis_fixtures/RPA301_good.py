"""GOOD: every accelerated family has a reference entry."""


def gap_ref(bits):
    return bits


def gap_fast(bits):
    return bits


KERNELS = {"gap": gap_ref}

for _k, _fn in KERNELS.items():
    register(_k, "reference", _fn)

register("gap", "accelerated", gap_fast)


def register(name, backend, fn):
    pass

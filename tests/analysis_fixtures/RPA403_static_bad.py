"""BAD: the module registers dynamically but keeps a parallel static
COUNTER_BASED tuple — it drifts the moment any plugin registers."""
from repro.rng.sources import register_generator


def ext_block(seed, stream, n, offset=None):
    return (seed, stream, n, offset)


register_generator("ext", ext_block, counter_based=True)

COUNTER_BASED = ("ext",)

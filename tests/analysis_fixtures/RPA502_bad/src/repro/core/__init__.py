"""Battery-system root for the reachability fixture."""
from repro import helper  # noqa: F401

# repro: quarantine -- allegedly dead (but the root imports it)
"""A quarantined module that live code still imports."""

HELPS = True

"""BAD: the writer's leaf layout has no reader upgrade path."""
import numpy as np

from repro.ckpt import io


class Snapshot:
    def __init__(self, done=0, total=0):
        self.done = done
        self.total = total

    def save(self, path):
        io.save(path, [np.int64(self.done), np.int64(self.total),
                       np.int64(0), np.int64(0), np.int64(0)])

    @classmethod
    def load(cls, path):
        leaves = io.load_flat(path)
        if len(leaves) == 3:
            return cls(int(leaves[0]), int(leaves[1]))
        raise ValueError("unknown snapshot layout")

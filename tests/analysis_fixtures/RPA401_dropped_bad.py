"""BAD: a generator takes offset= but is missing from COUNTER_BASED —
its jump-ahead capability is dropped at the offset dispatch."""


def a_block(seed, stream, n, offset=0):
    return (seed, stream, n, offset)


def b_block(seed, stream, n, offset=0):
    return (seed, stream, n, offset)


GENERATORS = {"a": a_block, "b": b_block}
COUNTER_BASED = ("a",)

"""BAD: a registered source never declares its offset capability —
counter_based cannot be inferred from an out-of-repo block function."""
from repro.rng.sources import register_generator


def ext_block(seed, stream, n, offset=None):
    return (seed, stream, n, offset)


register_generator("ext", ext_block)

"""BAD: a generator declared COUNTER_BASED takes no offset param —
jump-ahead would silently restart its stream."""


def a_block(seed, stream, n, offset=0):
    return (seed, stream, n, offset)


def b_block(seed, stream, n):
    return (seed, stream, n)


GENERATORS = {"a": a_block, "b": b_block}
COUNTER_BASED = ("a", "b")

"""GOOD: integer reductions pinned, float reductions tracked."""
import jax.numpy as jnp


def _pinned_kernel(rows_ref, out_ref, acc_ref):
    rows = rows_ref[...]
    out_ref[...] = jnp.sum(rows & jnp.uint32(1), axis=1,
                           dtype=jnp.uint32)
    hits = (rows > 0).astype(jnp.float32)
    acc_ref[...] = jnp.sum(hits, axis=1)

"""GOOD: every RunSpec field is keyed or explicitly runtime-arg."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RunSpec:
    battery: str
    progress: bool = False  # repro: runtime-arg


class Session:
    def cache_key(self, spec):
        return (spec.battery,)

    def _compiled(self, spec):
        return compile_battery(spec.battery)


def compile_battery(battery):
    return battery

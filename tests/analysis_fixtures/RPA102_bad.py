"""BAD: float() concretizes a traced value (device sync + baked const)."""
import jax
import jax.numpy as jnp


@jax.jit
def scale_of(x):
    s = jnp.std(x)
    return x / float(s)

"""Backend-registry parity suite (ISSUE 4): for every registered kernel
family the accelerated (Pallas, interpret mode on CPU) implementation
must agree with the pure-jnp reference on (stat, p); the jump-ahead
generator blocks must be bit-identical to their sequential scan twins
(including mid-stream ``offset`` continuation); and a fixed-seed battery
must stitch the same verdict under ``backend=reference`` and
``backend=accelerated``."""
import numpy as np
import pytest

from repro.core import pool
from repro.core.api import PoolSession, RunSpec
from repro.core.battery import build_battery, split_entry
from repro.rng import generators as G
from repro.stats import backends as B

# family -> small/large parameterizations (the "2 scales" of the parity
# contract; sizes chosen so every code path engages at CI speed)
PARITY_CASES = {
    "gap": [dict(n=4096), dict(n=16384)],
    "poker": [dict(n=1024), dict(n=4096)],
    "weight": [dict(n=4096), dict(n=16384)],
    "serial2d": [dict(n=2048, d=16), dict(n=8192, d=32)],
    "collision": [dict(n=2048, kbits=14), dict(n=8192, kbits=16)],
    "rank": [dict(n_mats=256), dict(n_mats=512)],
    # no accelerated impl — the registry must fall back to reference
    "birthday": [dict(n=1024, tbits=24)],
    "coupon": [dict(n=4096, d=8)],
    "maxoft": [dict(n=2048, t=8)],
    "hamcorr": [dict(n=4096)],
    "pairstream": [dict(n=1024, mode="corr"), dict(n=1024, mode="match")],
}


def _bits(seed, n=262144):
    with G.x64():
        return G.splitmix64_block(seed, 1, n)


# ------------------------------------------------------------- registry

def test_registry_covers_every_family():
    assert B.families() == sorted(PARITY_CASES)
    assert B.accelerated_families() == sorted(
        ["gap", "poker", "weight", "serial2d", "collision", "rank"])


def test_resolve_and_auto():
    assert B.resolve("reference") == "reference"
    assert B.resolve("accelerated") == "accelerated"
    assert B.resolve("auto") in ("reference", "accelerated")
    with pytest.raises(KeyError):
        B.resolve("vectorized")


def test_fallback_for_unaccelerated_family():
    """A family without an accelerated impl resolves to its reference —
    a battery-wide backend choice always yields a full job table."""
    assert B.get_kernel("birthday", "accelerated") is B.get_kernel(
        "birthday", "reference")


# ------------------------------------------------- (stat, p) parity

@pytest.mark.parametrize("family", sorted(PARITY_CASES))
@pytest.mark.parametrize("seed", [1, 7, 31])
def test_accelerated_matches_reference(family, seed):
    ref = B.get_kernel(family, "reference")
    acc = B.get_kernel(family, "accelerated")
    bits = _bits(seed)
    for kw in PARITY_CASES[family]:
        s1, p1 = ref(bits, **kw)
        s2, p2 = acc(bits, **kw)
        np.testing.assert_allclose(float(s1), float(s2), rtol=1e-5,
                                   err_msg=f"{family} stat {kw}")
        np.testing.assert_allclose(float(p1), float(p2), rtol=1e-5,
                                   atol=1e-7, err_msg=f"{family} p {kw}")


def test_collision_large_urn_space_falls_back():
    """Above HIST_MAX_BINS the accelerated collision keeps the sort-based
    path (dense occupancy would not fit VMEM) — and still agrees."""
    bits = _bits(3)
    kw = dict(n=4096, kbits=24)          # 2^24 urns > HIST_MAX_BINS
    s1, p1 = B.get_kernel("collision", "reference")(bits, **kw)
    s2, p2 = B.get_kernel("collision", "accelerated")(bits, **kw)
    assert float(s1) == float(s2) and float(p1) == float(p2)


# --------------------------------------- jump-ahead generator bit-exactness

@pytest.mark.parametrize("gen", sorted(G.SCAN_REFERENCE))
@pytest.mark.parametrize("seed", [0, 9, 123])
def test_jump_matches_scan(gen, seed):
    jump, scan = G.GENERATORS[gen], G.SCAN_REFERENCE[gen]
    with G.x64():
        for n in (37, 1024):
            a = np.asarray(jump(seed, 5, n))
            b = np.asarray(scan(seed, 5, n))
            assert (a == b).all(), (gen, n)


@pytest.mark.parametrize("gen", sorted(G.SCAN_REFERENCE))
def test_jump_offset_continuation(gen):
    """Mid-stream continuation: block(n)[k:] == block(n-k, offset=k) —
    the property that lets the former scan generators join
    COUNTER_BASED."""
    jump = G.GENERATORS[gen]
    with G.x64():
        full = np.asarray(jump(11, 2, 300))
        for k in (1, 128, 299):
            tail = np.asarray(jump(11, 2, 300 - k, offset=k))
            assert (full[k:] == tail).all(), (gen, k)


def test_counter_based_complement_is_mwc():
    """Every generator except mwc is counter-based now (jump-ahead gave
    the linear recurrences exact offset continuation)."""
    assert set(G.GENERATORS) - set(G.COUNTER_BASED) == {"mwc"}


# ------------------------------------------------- battery-level threading

def test_build_battery_binds_backend():
    ref = build_battery("smallcrush", 0.125, backend="reference")
    acc = build_battery("smallcrush", 0.125, backend="accelerated")
    assert all(e.backend == "reference" for e in ref)
    assert all(e.backend == "accelerated" for e in acc)
    # identical table geometry: same names, words, costs — only kernels
    assert [(e.name, e.n_words, e.cost) for e in ref] == \
           [(e.name, e.n_words, e.cost) for e in acc]
    sub = split_entry(acc[4], 2, start_index=0)
    assert all(s.backend == "accelerated" for s in sub)


def test_runspec_backend_validation():
    with pytest.raises(KeyError):
        RunSpec("smallcrush", backend="gpu")
    assert RunSpec("smallcrush").backend == "auto"


def test_bucketed_blocks_bound_waste():
    """Power-of-two bucketing keeps generated/read <= 1.25 on smallcrush
    (the acceptance bound) and < the old battery-wide-max ratio."""
    for scale in (0.125, 1.0):
        entries = build_battery("smallcrush", scale)
        ratio = pool.block_ratio(entries)
        legacy = (len(entries) * max(e.n_words for e in entries)
                  / pool.read_words(entries))
        assert 1.0 <= ratio <= 1.25, (scale, ratio)
        assert ratio < legacy, (scale, ratio, legacy)
    assert pool.word_bucket(0) == 0
    assert pool.word_bucket(1) == 1
    assert pool.word_bucket(4096) == 4096
    assert pool.word_bucket(4097) == 8192


def test_smallcrush_verdict_identical_across_backends():
    """Acceptance: a fixed-seed smallcrush run stitches the same p-values
    and the same verdict under backend=reference and
    backend=accelerated, from one session (distinct cache slots)."""
    session = PoolSession()
    res = {}
    for backend in ("reference", "accelerated"):
        res[backend] = session.submit(
            RunSpec("smallcrush", "pcg32", seeds=17, scale=0.0625,
                    backend=backend)).result()
    ref, acc = res["reference"], res["accelerated"]
    assert ref.verdict.decision == acc.verdict.decision
    assert sorted(ref.results) == sorted(acc.results)
    for i in ref.results:
        np.testing.assert_allclose(ref.results[i][1], acc.results[i][1],
                                   rtol=1e-5, atol=1e-7)
    # the two backends compiled as separate cache slots, not one
    keys = {k[-1] for k in session.trace_counts}
    assert keys == {"reference", "accelerated"}

"""Golden-key tests for the CLI ``--json`` schema — the machine-readable
contract README.md and the CI gates consume. If a field is renamed or
dropped, these fail before any README example rots."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RUN_KEYS = {"battery", "scale", "workers", "policy", "backend",
            "backend_resolved", "adaptive", "alpha", "resizes", "seed",
            "wall_s", "rounds_run", "retries", "plan_rounds", "runs"}
PER_GEN_KEYS = {"suspects", "verdict", "tests_checked", "failed_tests",
                "rounds_run", "tests"}
TEST_KEYS = {"index", "name", "stat", "p", "suspect"}
CAMPAIGN_TOP_KEYS = {"battery", "workers", "policy", "backend",
                     "backend_resolved", "alpha", "seed", "wall_s",
                     "rounds_run", "campaign"}
CAMPAIGN_KEYS = {"n_streams", "waves", "span", "phases", "stream_check",
                 "survivors", "knockouts", "undecided", "cells"}
CELL_KEYS = {"gen", "stream", "decision", "phase"}
SERVE_KEYS = {"state", "max_wait", "tickets", "batches",
              "dispatch_rounds", "cache", "resubmit", "traces"}
SERVE_TICKET_KEYS = {"ticket", "gen", "state", "batch", "cache_hits"}
SERVE_RESUBMIT_KEYS = {"ticket", "cache_hits", "done_at_submit",
                       "dispatches_added"}
FAULTS_KEYS = {"plan", "events", "quarantines"}
FAULT_EVENT_KEYS = {"round", "kind", "slot", "job", "rule", "detail"}
EVIDENCE_KEYS = {"engine", "threshold", "runs"}
EVIDENCE_RUN_KEYS = {"wealth", "log_wealth", "trajectory"}
CAMPAIGN_EVIDENCE_KEYS = {"engine", "threshold", "continuations", "cells"}
EVIDENCE_CELL_KEYS = {"gen", "stream", "wealth", "log_wealth"}


def _cli(json_path, *args):
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.battery",
         "--json", json_path, *args],
        env=env, cwd=REPO, capture_output=True, text=True)
    assert os.path.exists(json_path), (
        f"CLI wrote no json report (exit {p.returncode}):\n"
        f"{p.stdout[-2000:]}\n{p.stderr[-2000:]}")
    with open(json_path) as f:
        return p.returncode, json.load(f)


@pytest.fixture(scope="module")
def battery_report(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("cli") / "run.json")
    code, rep = _cli(path, "--battery", "smallcrush", "--gen",
                     "splitmix64,randu", "--scale", "0.0625", "--seed",
                     "7", "--adaptive", "--resize-at", "1:1")
    return code, rep


def test_battery_json_golden_keys(battery_report):
    _, rep = battery_report
    assert set(rep) == RUN_KEYS
    assert set(rep["runs"]) == {"splitmix64", "randu"}
    for run in rep["runs"].values():
        assert set(run) == PER_GEN_KEYS
        for t in run["tests"]:
            assert set(t) == TEST_KEYS


def test_battery_json_backend_fields(battery_report):
    _, rep = battery_report
    assert rep["backend"] in ("auto", "reference", "accelerated")
    assert rep["backend_resolved"] in ("reference", "accelerated")


def test_battery_json_resize_fields(battery_report):
    _, rep = battery_report
    assert isinstance(rep["resizes"], list) and rep["resizes"]
    assert set(rep["resizes"][0]) == {"round", "workers"}


def test_battery_json_verdict_fields(battery_report):
    code, rep = battery_report
    assert rep["adaptive"] is True
    assert rep["runs"]["randu"]["verdict"] == "FAIL"    # canary
    assert rep["runs"]["splitmix64"]["verdict"] in ("PASS", "UNDECIDED")
    assert code == 1                                    # randu failed


def test_serve_json_golden_keys(tmp_path):
    """--serve adds EXACTLY one top-level key ("serve") to the run
    payload — and only under --serve, so the classic schema is
    untouched — carrying the ticket table, the coalescing counters and
    the resubmit cache-hit demo."""
    path = str(tmp_path / "serve.json")
    code, rep = _cli(path, "--battery", "smallcrush", "--gen",
                     "splitmix64,pcg32", "--scale", "0.01", "--seed",
                     "7", "--serve", "--serve-resubmit",
                     "--serve-state", str(tmp_path / "state"))
    assert code == 0
    assert set(rep) == RUN_KEYS | {"serve"}
    serve = rep["serve"]
    assert set(serve) == SERVE_KEYS
    assert serve["batches"] == 1            # two clients, ONE batch
    assert len(serve["tickets"]) == 2
    for t in serve["tickets"]:
        assert set(t) == SERVE_TICKET_KEYS
        assert t["state"] == "done" and t["batch"] == 0
    resub = serve["resubmit"]
    assert set(resub) == SERVE_RESUBMIT_KEYS
    assert resub["done_at_submit"] is True
    assert resub["dispatches_added"] == 0   # served from the cache
    assert resub["cache_hits"] == 1
    assert set(rep["runs"]) == {"splitmix64", "pcg32"}
    for run in rep["runs"].values():
        assert set(run) == PER_GEN_KEYS


def test_inject_json_golden_keys(tmp_path):
    """--inject adds EXACTLY one top-level key ("faults") to the run
    payload — and only under --inject, so the classic schema is
    untouched — carrying the plan echo and the fault/quarantine ledger,
    while the verdict survives the injected faults (exit 0)."""
    plan = str(tmp_path / "plan.json")
    with open(plan, "w") as f:
        json.dump({"seed": 7, "rules": [
            {"kind": "evict", "round": 0, "slot": 0},
            {"kind": "corrupt", "round": 1, "slot": 0}]}, f)
    path = str(tmp_path / "chaos.json")
    code, rep = _cli(path, "--battery", "smallcrush", "--gen",
                     "splitmix64", "--scale", "0.01", "--seed", "7",
                     "--inject", plan)
    assert code == 0                        # faults degraded, not failed
    assert set(rep) == RUN_KEYS | {"faults"}
    faults = rep["faults"]
    assert set(faults) == FAULTS_KEYS
    assert faults["plan"]["seed"] == 7
    assert len(faults["plan"]["rules"]) == 2
    kinds = [e["kind"] for e in faults["events"]]
    assert kinds == ["evict", "corrupt", "corrupt_result"]
    for e in faults["events"]:
        assert set(e) == FAULT_EVENT_KEYS
    assert rep["retries"] == 1              # held jobs retried to PASS
    assert rep["runs"]["splitmix64"]["verdict"] == "PASS"


def test_evidence_json_golden_keys(tmp_path):
    """--verdict-engine evalue adds EXACTLY one top-level key
    ("evidence") to the run payload — and only under a non-default
    engine, so the classic schema is untouched — carrying each
    generator's e-process wealth and full per-test trajectory."""
    path = str(tmp_path / "evidence.json")
    code, rep = _cli(path, "--battery", "smallcrush", "--gen",
                     "splitmix64,randu", "--scale", "0.0625", "--seed",
                     "7", "--adaptive", "--verdict-engine", "evalue")
    assert code == 1                            # randu FAILs (canary)
    assert set(rep) == RUN_KEYS | {"evidence"}
    ev = rep["evidence"]
    assert set(ev) == EVIDENCE_KEYS
    assert ev["engine"] == "evalue"
    assert ev["threshold"] == pytest.approx(1.0 / rep["alpha"])
    assert set(ev["runs"]) == {"splitmix64", "randu"}
    for gen, run in ev["runs"].items():
        assert set(run) == EVIDENCE_RUN_KEYS
        assert run["wealth"] == pytest.approx(
            run["trajectory"][-1], rel=1e-6)
    assert ev["runs"]["randu"]["wealth"] >= ev["threshold"]
    assert ev["runs"]["splitmix64"]["wealth"] < ev["threshold"]
    assert rep["runs"]["randu"]["verdict"] == "FAIL"
    # and the per-gen schema is byte-compatible with the classic run
    for run in rep["runs"].values():
        assert set(run) == PER_GEN_KEYS


def test_campaign_evidence_json_golden_keys(tmp_path):
    """The campaign payload's conditional "evidence" section: engine,
    threshold, continuation count and per-cell wealth."""
    path = str(tmp_path / "campaign-ev.json")
    code, rep = _cli(path, "--campaign", "--battery", "smallcrush",
                     "--gen", "splitmix64,randu", "--streams", "2",
                     "--waves", "0.0625", "--seed", "7",
                     "--verdict-engine", "evalue")
    assert code == 0
    assert set(rep) == CAMPAIGN_TOP_KEYS | {"evidence"}
    assert set(rep["campaign"]) == CAMPAIGN_KEYS
    ev = rep["evidence"]
    assert set(ev) == CAMPAIGN_EVIDENCE_KEYS
    assert ev["engine"] == "evalue"
    assert ev["threshold"] == pytest.approx(1.0 / rep["alpha"])
    assert ev["continuations"] >= 0
    assert len(ev["cells"]) == 4
    for cell in ev["cells"]:
        assert set(cell) == EVIDENCE_CELL_KEYS
    # every cell FAILed in a WAVE phase crossed the Ville boundary
    # (a seam-phase knockout never accumulates wealth — knockout-only)
    phases = rep["campaign"]["phases"]
    decided = {(c["gen"], c["stream"]): c
               for c in rep["campaign"]["cells"]}
    wave_fails = 0
    for cell in ev["cells"]:
        d = decided[(cell["gen"], cell["stream"])]
        if (d["decision"] == "FAIL" and d["phase"] is not None
                and phases[d["phase"]] != "streamcheck"):
            wave_fails += 1
            assert cell["wealth"] >= ev["threshold"]
    assert all(decided[("randu", s)]["decision"] == "FAIL"
               for s in (0, 1))


def test_campaign_json_golden_keys(tmp_path):
    path = str(tmp_path / "campaign.json")
    code, rep = _cli(path, "--campaign", "--battery", "smallcrush",
                     "--gen", "splitmix64,randu", "--streams", "2",
                     "--waves", "0.0625", "--seed", "7")
    assert code == 0                # completed screening exits 0
    assert set(rep) == CAMPAIGN_TOP_KEYS
    camp = rep["campaign"]
    assert set(camp) == CAMPAIGN_KEYS
    assert camp["n_streams"] == 2 and camp["waves"] == [0.0625]
    assert camp["phases"][0] == "streamcheck"
    assert len(camp["cells"]) == 4
    for cell in camp["cells"]:
        assert set(cell) == CELL_KEYS
        assert cell["decision"] in ("PASS", "FAIL", "UNDECIDED")
    by_gen = {c["gen"]: c["decision"] for c in camp["cells"]}
    assert by_gen["randu"] == "FAIL"
    assert by_gen["splitmix64"] == "PASS"
    assert camp["survivors"] + camp["knockouts"] == 4
    assert camp["undecided"] == 0

"""Tests for the public session API: RunSpec normalization, the
PoolSession compile cache (trace counting), checkpoint resume, multi-
generator fan-out, schedule-policy registry, and over-decomposition."""
import dataclasses

import numpy as np
import pytest

from repro.core import stitch
from repro.core.api import BatteryResult, Checkpoint, PoolSession, RunSpec
from repro.core.battery import build_battery, split_entry
from repro.core.policies import (
    OverDecomposePolicy,
    RetryPolicy,
    get_policy,
    register_policy,
)
from repro.core.pool import stream_table

SCALE = 0.125


@pytest.fixture(scope="module")
def session():
    return PoolSession()


# ------------------------------------------------------------------ RunSpec

def test_runspec_normalizes_scalars():
    spec = RunSpec("smallcrush", "splitmix64", 3)
    assert spec.generators == ("splitmix64",)
    assert spec.seeds == (3,)


def test_runspec_broadcasts_seeds():
    spec = RunSpec("smallcrush", ("splitmix64", "pcg32"), 3)
    assert spec.seeds == (3, 3)
    spec2 = RunSpec("smallcrush", ("splitmix64", "pcg32"), (3, 4))
    assert spec2.seeds == (3, 4)


def test_runspec_validates():
    with pytest.raises(KeyError):
        RunSpec("megacrush", "splitmix64", 1)
    with pytest.raises(KeyError):
        RunSpec("smallcrush", "notagen", 1)
    with pytest.raises(ValueError):
        RunSpec("smallcrush", ("splitmix64", "pcg32"), (1, 2, 3))
    with pytest.raises(ValueError):
        RunSpec("smallcrush", "splitmix64", 1, policy="nope")


def test_runspec_frozen_and_hashable():
    spec = RunSpec("smallcrush", "splitmix64", 3)
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.scale = 2.0
    assert spec == RunSpec("smallcrush", "splitmix64", 3)
    assert hash(spec) == hash(RunSpec("smallcrush", "splitmix64", 3))


def test_runspec_preset_folds_battery_config():
    assert RunSpec.preset("bigcrush").scale == 16.0
    assert RunSpec.preset("crush").n_tests == 96
    assert RunSpec.preset("bigcrush", scale=0.5).scale == 0.5


# ------------------------------------------------------------ compile cache

def test_compile_cache_single_trace_across_generators():
    """Two submits with the same (battery, scale, workers) but different
    generators must trace the round program exactly once."""
    session = PoolSession()
    r1 = session.submit(RunSpec("smallcrush", "splitmix64", 7,
                                scale=SCALE)).result()
    r2 = session.submit(RunSpec("smallcrush", "pcg32", 13,
                                scale=SCALE)).result()
    assert session.total_traces == 1
    assert len(r1.results) == len(r2.results) == 10
    key = session.cache_key(RunSpec("smallcrush", "splitmix64", 0,
                                    scale=SCALE))
    assert session.trace_counts == {key: 1}


def test_compile_cache_keyed_on_battery_and_scale():
    session = PoolSession()
    session.submit(RunSpec("smallcrush", "splitmix64", 1,
                           scale=SCALE)).result()
    session.submit(RunSpec("smallcrush", "splitmix64", 1,
                           scale=SCALE / 2)).result()
    assert session.total_traces == 2            # different scale -> new key
    assert len(session.trace_counts) == 2


# --------------------------------------------------------- checkpoint resume

def test_checkpoint_resume_runs_only_missing(tmp_path):
    """save -> knock entries out -> restart re-runs only the missing
    indices and reconciles bitwise (deterministic streams)."""
    ck = str(tmp_path / "resume.ck")
    session = PoolSession()
    spec = RunSpec("smallcrush", "splitmix64", 11, scale=SCALE,
                   checkpoint_path=ck)
    res1 = session.submit(spec).result()
    assert res1.rounds_run > 0

    Checkpoint.load(ck).drop([2, 8]).save(ck)

    run2 = session.submit(spec)
    status = run2.status()
    assert status["jobs_total"] - status["jobs_done"] == 2
    res2 = run2.result()
    w = session.n_workers
    assert res2.rounds_run == -(-2 // w)         # one replan round set
    assert res2.results == res1.results          # bitwise reconciliation
    assert session.total_traces == 1             # cache hit on restart


# ------------------------------------------------------------------ fan-out

def test_multi_generator_fanout_matches_single_runs(session):
    """G generators in one dispatch == the same generators run alone."""
    spec = RunSpec("smallcrush", ("splitmix64", "pcg32", "randu"), 7,
                   scale=SCALE)
    multi = session.submit(spec).result()
    assert isinstance(multi, BatteryResult)
    assert set(multi.runs) == {"splitmix64", "pcg32", "randu"}
    for gen in spec.generators:
        single = session.submit(RunSpec("smallcrush", gen, 7,
                                        scale=SCALE)).result()
        for i in range(10):
            assert np.isclose(multi.runs[gen].results[i][1],
                              single.results[i][1], rtol=1e-6,
                              equal_nan=True), (gen, i)
    assert multi.runs["randu"].n_suspect >= 2    # canary still flagged
    assert multi.runs["splitmix64"].n_suspect == 0


# ----------------------------------------------------------------- policies

def test_policy_registry():
    assert get_policy("lpt").name == "lpt"
    assert get_policy("roundrobin").name == "roundrobin"
    assert get_policy("over_decompose").name == "over_decompose"
    pol = OverDecomposePolicy(max_parts=3)
    assert get_policy(pol) is pol
    with pytest.raises(ValueError):
        get_policy("not_a_policy")


def test_register_custom_policy():
    base = get_policy("lpt")

    @dataclasses.dataclass(frozen=True)
    class Reversed:
        name: str = "reversed_rr"

        def plan(self, costs, n_workers):
            return get_policy("roundrobin").plan(list(costs)[::-1], n_workers)

        def decompose(self, entries, n_workers):
            return None

        def signature(self):
            return None

    register_policy(Reversed())
    assert get_policy("reversed_rr").name == "reversed_rr"
    assert get_policy("lpt") is base


# ----------------------------------------------------------- over_decompose

def test_split_entry_shrinks_and_groups():
    entries = build_battery("smallcrush", 1.0)   # full size: floors don't bind
    heavy = entries[7]                           # rank: the heaviest kernel
    subs = split_entry(heavy, 4, start_index=20)
    assert [s.index for s in subs] == [20, 21, 22, 23]
    assert all(s.group == heavy.index for s in subs)
    assert all(s.n_parts == len(subs) for s in subs)
    assert all(s.n_words < heavy.n_words for s in subs)
    assert sum(s.cost for s in subs) <= heavy.cost + 1e-9
    # floors binding -> refuse to split rather than emit useless sub-jobs
    tiny = build_battery("smallcrush", SCALE)[7]
    assert len(split_entry(tiny, 4, start_index=0)) == 1


def test_decompose_covers_all_tests_with_unique_streams():
    entries = build_battery("smallcrush", SCALE)
    jobs = OverDecomposePolicy(threshold=0.05, max_parts=4).decompose(
        entries, n_workers=8)
    assert jobs is not None and len(jobs) > len(entries)
    assert sorted({j.group for j in jobs}) == [e.index for e in entries]
    assert [j.index for j in jobs] == list(range(len(jobs)))
    streams = stream_table(jobs)
    assert len(set(streams.tolist())) == len(jobs)


def test_over_decompose_end_to_end(session):
    pol = OverDecomposePolicy(threshold=0.05, max_parts=4)
    res = session.submit(RunSpec("smallcrush", "splitmix64", 7, scale=SCALE,
                                 policy=pol)).result()
    assert len(res.results) == 10                # combined back to test space
    assert all(0.0 <= res.results[i][1] <= 1.0 for i in range(10))
    assert res.n_suspect == 0                    # good generator stays good
    bad = session.submit(RunSpec("smallcrush", "randu", 7, scale=SCALE,
                                 policy=pol)).result()
    assert bad.n_suspect >= 2                    # canary survives the combine


def test_combiners():
    stat, p = stitch.combine_stouffer([0.5, 0.5, 0.5])
    assert abs(stat) < 1e-9 and abs(p - 0.5) < 1e-9
    _, p_low = stitch.combine_stouffer([1e-9, 1e-9])
    assert p_low < 1e-6
    _, p_high = stitch.combine_stouffer([1 - 1e-9, 1 - 1e-9])
    assert p_high > 1 - 1e-6                     # both tails preserved
    stat_f, p_f = stitch.combine_fisher([1e-9, 1e-9])
    assert p_f < 1e-6
    _, p_null = stitch.combine_fisher([0.5, 0.5, 0.5, 0.5])
    assert 0.01 < p_null < 0.99


def test_fold_groups_passthrough_is_bitwise():
    entries = build_battery("smallcrush", SCALE)
    job_results = {e.index: (1.0 + e.index, 0.25) for e in entries}
    out = stitch.fold_groups(job_results, entries)
    assert out == job_results                    # no combine applied

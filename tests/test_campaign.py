"""Campaign subsystem tests (ISSUE 5, DESIGN.md §8): stream-offset
grids, the pairstream seam family, wave planning, the knockout loop,
batched-dispatch trace accounting, and ledger resume."""
import numpy as np
import pytest

from repro.core import stitch
from repro.core.api import (CELL_FAIL, CELL_PASS, CELL_UNDECIDED,
                            CampaignLedger, CampaignSpec, PoolSession,
                            RunSpec)
from repro.core.campaign import Campaign, default_span, screen
from repro.core.scheduler import wave_makespan, wave_schedule
from repro.rng import generators as G
from repro.stats.tests import pairstream

SCALE = 0.0625


@pytest.fixture(scope="module")
def session():
    return PoolSession()


# ------------------------------------------------------- offset machinery

def test_stream_offsets_grid():
    assert G.stream_offsets(4, 100).tolist() == [0, 100, 200, 300]
    with pytest.raises(ValueError):
        G.stream_offsets(0, 100)


def test_seam_offsets_straddle():
    # pair s reads [ (s+1)*span - n, (s+1)*span + n )
    assert G.seam_offsets(3, 1000, 64).tolist() == [936, 1936]
    assert G.seam_offsets(1, 1000, 64).size == 0
    with pytest.raises(ValueError):
        G.seam_offsets(3, 100, 200)        # seam block wider than span


def test_runspec_offsets_normalize_and_validate():
    spec = RunSpec("smallcrush", ("splitmix64", "pcg32"), 1,
                   offsets=(0, 4096))
    assert spec.offsets == (0, 4096)
    spec1 = RunSpec("smallcrush", ("splitmix64", "pcg32"), 1, offsets=64)
    assert spec1.offsets == (64, 64)            # broadcast
    with pytest.raises(ValueError):
        RunSpec("smallcrush", "mwc", 1, offsets=64)    # no jump-ahead
    with pytest.raises(ValueError):
        RunSpec("smallcrush", "splitmix64", 1, offsets=-1)
    with pytest.raises(ValueError):
        RunSpec("smallcrush", ("splitmix64", "pcg32"), 1,
                offsets=(1, 2, 3))


def test_grid_dispatch_offset_zero_matches_classic(session):
    """offsets=(0, 0) routes through the grid runner but must reproduce
    the classic fan-out results bitwise (the 64-bit ladder fallback is
    exact for any offset, including 0)."""
    classic = session.submit(RunSpec(
        "smallcrush", ("splitmix64", "randu"), 7, scale=SCALE)).result()
    grid = session.submit(RunSpec(
        "smallcrush", ("splitmix64", "randu"), 7, scale=SCALE,
        offsets=0)).result()
    for gen in ("splitmix64", "randu"):
        assert grid.runs[gen].results == classic.runs[gen].results


def test_grid_dispatch_offset_reads_substream(session):
    """A non-zero offset must change the words every job consumes (the
    cell reads its own sub-stream), while staying a valid battery."""
    a = session.submit(RunSpec("smallcrush", "splitmix64", 7, scale=SCALE,
                               offsets=0)).result()
    b = session.submit(RunSpec("smallcrush", "splitmix64", 7, scale=SCALE,
                               offsets=(1 << 16,))).result()
    assert a.results != b.results
    assert all(0.0 <= b.results[i][1] <= 1.0 for i in range(10))


# ------------------------------------------------------- pairstream family

def test_pairstream_null_is_calibrated():
    with G.x64():
        bits = G.splitmix64_block(3, 5, 8192)
    for mode in ("corr", "hamcorr", "match", "shift"):
        _, p = pairstream(bits, n=4096, mode=mode)
        assert 1e-4 < float(p) < 1.0 - 1e-4, mode


def test_pairstream_catches_duplicated_stream():
    """If the two halves are the SAME words (span-0 overlap bug), the
    match mode must blow up."""
    with G.x64():
        half = G.splitmix64_block(3, 5, 4096)
    bits = np.concatenate([np.asarray(half), np.asarray(half)])
    _, p = pairstream(bits, n=4096, mode="match")
    assert float(p) < 1e-10


def test_pairstream_catches_off_by_k_seam():
    """An off-by-two seam (stream s+1 starting 2 words early) is exactly
    what the shift mode exists for."""
    with G.x64():
        blk = np.asarray(G.splitmix64_block(3, 5, 8194))
    bits = np.concatenate([blk[:4096], blk[4094:8190]])
    _, p = pairstream(bits, n=4096, mode="shift")
    assert float(p) < 1e-10


def test_battery_pairstream_builds():
    from repro.core.battery import build_battery
    entries = build_battery("pairstream", 0.25)
    assert len(entries) == 4
    assert len({e.n_words for e in entries}) == 1    # one seam alignment


# -------------------------------------------------------- wave planning

def test_wave_schedule_sorts_ascending():
    assert wave_schedule((1.0, 0.25, 0.5)) == [0.25, 0.5, 1.0]
    assert wave_schedule((0.25, 0.25)) == [0.25, 0.25]
    with pytest.raises(ValueError):
        wave_schedule(())
    with pytest.raises(ValueError):
        wave_schedule((0.5, -1.0))


def test_wave_makespan_models_batching():
    batched, per_cell = wave_makespan([1.0] * 10, 2, 16)
    assert per_cell == pytest.approx(batched * 16)


# ---------------------------------------------------------- spec + ledger

def test_campaign_spec_validates():
    with pytest.raises(ValueError):
        CampaignSpec("smallcrush", ("mwc",), n_streams=2)   # no jump-ahead
    with pytest.raises(ValueError):
        CampaignSpec("smallcrush", ("splitmix64", "splitmix64"))
    with pytest.raises(ValueError):
        CampaignSpec("smallcrush", ("splitmix64",), waves=())
    with pytest.raises(KeyError):
        CampaignSpec("megacrush", ("splitmix64",))
    spec = CampaignSpec("smallcrush", ("splitmix64", "pcg32"), n_streams=3)
    assert spec.n_cells == 6
    assert spec.cells[0] == ("splitmix64", 0)
    assert spec.cells[-1] == ("pcg32", 2)


def test_campaign_ledger_roundtrip(tmp_path):
    spec = CampaignSpec("smallcrush", ("splitmix64", "pcg32"), n_streams=2)
    led = CampaignLedger.fresh(spec)
    led.decisions[3] = CELL_FAIL
    led.decided_phase[3] = 1
    led.phases_done = 2
    path = str(tmp_path / "campaign.ck")
    led.save(path)
    back = CampaignLedger.load(path)
    assert back.matches(spec)
    assert back.phases_done == 2
    assert back.decisions.tolist() == led.decisions.tolist()
    assert back.decided_phase.tolist() == led.decided_phase.tolist()
    other = CampaignSpec("smallcrush", ("splitmix64", "pcg32"), n_streams=3)
    assert not back.matches(other)
    # same grid, different decision-relevant config -> digest refuses
    assert not back.matches(CampaignSpec(
        "smallcrush", ("splitmix64", "pcg32"), n_streams=2, waves=(0.5,)))
    assert not back.matches(CampaignSpec(
        "smallcrush", ("splitmix64", "pcg32"), n_streams=2, seed=99))


def test_default_span_covers_widest_block():
    from repro.core.battery import build_battery, max_words
    spec = CampaignSpec("smallcrush", ("splitmix64",), n_streams=4,
                        waves=(SCALE, 0.125))
    span = default_span(spec)
    assert span >= max_words(build_battery("smallcrush", 0.125))
    assert span & (span - 1) == 0                    # power of two


# ------------------------------------------------- the acceptance campaign

GENS8 = ("splitmix64", "msweyl", "threefry", "pcg32", "lcg64",
         "xorshift64s", "randu", "minstd")


def test_campaign_8x4_acceptance(tmp_path):
    """ISSUE 5 acceptance: >= 8 generators x 4 stream offsets complete
    smallcrush with one batched dispatch per wave — compile count scales
    with PHASES, not with the 32 cells — producing a per-cell matrix
    with knocked-out cells skipping later waves, resumable from the
    ledger."""
    ledger = str(tmp_path / "campaign.ck")
    session = PoolSession()
    spec = CampaignSpec("smallcrush", GENS8, n_streams=4, seed=7,
                        waves=(SCALE, SCALE), ledger_path=ledger)
    campaign = Campaign(session, spec)
    phases = campaign.phases()
    assert [p.name for p in phases] == ["streamcheck",
                                        f"x{SCALE:g}", f"x{SCALE:g}"]
    res = campaign.run()

    # one batched dispatch per wave: every phase compiled at most one
    # grid program — 32 cells never caused per-cell recompiles
    assert session.total_traces <= len(phases)
    # ... and the two same-scale waves shared ONE executable (the second
    # wave's survivor count pads back to a seen power-of-two bucket)
    assert session.total_traces == len(phases) - 1

    # the matrix: randu knocked out (stream check or wave 1 — never the
    # final wave), the robust generators pass every cell
    mat = res.matrix
    assert mat.shape == (8, 4)
    gidx = {g: i for i, g in enumerate(GENS8)}
    assert set(mat[gidx["randu"]].tolist()) == {CELL_FAIL}
    assert int(res.decided_phase.reshape(8, 4)[gidx["randu"]].max()) \
        < len(phases) - 1                            # skipped later waves
    for good in ("splitmix64", "threefry", "pcg32", "lcg64"):
        assert set(mat[gidx[good]].tolist()) == {CELL_PASS}, good
    assert not np.any(mat == CELL_UNDECIDED)
    assert "campaign screening matrix" in res.report

    # ledger resume: a fresh campaign over the same ledger replays
    # NOTHING and reports the identical matrix
    session2 = PoolSession()
    res2 = Campaign(session2, spec).run()
    assert res2.rounds_run == 0
    assert session2.total_traces == 0
    assert res2.decisions.tolist() == res.decisions.tolist()
    assert res2.decided_phase.tolist() == res.decided_phase.tolist()


def test_campaign_mid_run_resume(tmp_path):
    """A campaign interrupted between phases resumes at the next phase:
    decided cells stay decided, completed phases are not re-run."""
    ledger = str(tmp_path / "campaign.ck")
    spec = CampaignSpec("smallcrush", ("splitmix64", "randu"), n_streams=2,
                        seed=7, waves=(SCALE,), ledger_path=ledger)
    session = PoolSession()
    c1 = Campaign(session, spec)
    phases = c1.phases()
    c1._run_phase(0, phases[0])                  # stream check only
    c1.ledger.phases_done = 1
    c1._save_ledger()
    rounds_phase0 = c1.rounds_run
    assert np.all(np.asarray(c1.ledger.decisions).reshape(2, 2)[1]
                  == CELL_FAIL)                  # randu seam-knocked

    c2 = Campaign(session, spec)
    assert c2.ledger.phases_done == 1
    res = c2.run()
    wave_rounds = -(-10 // session.n_workers)    # smallcrush jobs / width
    assert 0 < res.rounds_run <= wave_rounds     # phase 0 was NOT re-run
    assert rounds_phase0 > 0
    mat = res.matrix
    assert set(mat[0].tolist()) == {CELL_PASS}   # splitmix64
    assert set(mat[1].tolist()) == {CELL_FAIL}   # randu stays knocked out


def test_campaign_knockout_skips_later_phases(session):
    """_phase_cells: a knocked-out cell contributes no work to any later
    phase (wave or seam)."""
    spec = CampaignSpec("smallcrush", ("splitmix64", "randu"), n_streams=2,
                        waves=(SCALE, 1.0))
    c = Campaign(session, spec)
    c.ledger.decisions[2:] = CELL_FAIL           # knock out randu's cells
    wave = [p for p in c.phases() if p.offset_rule == "stream"][0]
    assert c._phase_cells(wave) == [(0,), (1,)]
    seam = c.phases()[0]
    assert seam.offset_rule == "seam"
    assert c._phase_cells(seam) == [(0, 1)]      # only the surviving pair


def test_screen_one_call(tmp_path):
    """The one-call helper: no streams, no seam phase, single wave."""
    res = screen(CampaignSpec("smallcrush", ("splitmix64",), seed=3,
                              waves=(SCALE,), stream_check=True))
    assert res.phase_names == [f"x{SCALE:g}"]    # n_streams=1: no seams
    assert res.decision("splitmix64", 0) == stitch.PASS


# ---------------------------------------------------------- stitch report

def test_campaign_matrix_and_report():
    dec = [CELL_PASS, CELL_FAIL, CELL_UNDECIDED, CELL_PASS]
    mat = stitch.campaign_matrix(dec, 2, 2)
    assert mat.tolist() == [[1, 2], [0, 1]]
    rep = stitch.campaign_report(["alpha", "beta"], 2, dec,
                                 [1, 0, -1, 2], ["streamcheck", "x1", "x2"])
    assert "P@1" in rep and "F@0" in rep and "?" in rep
    assert "knocked out 1 cell(s)" in rep
    with pytest.raises(ValueError):
        stitch.campaign_matrix(dec, 3, 2)

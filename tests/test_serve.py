"""Serve-layer tests: the hold/release retry-budget bug sweep on
``BatteryRun`` (manual release must not spend the driver's budget,
``stream()`` must drive retry rounds, cancellation must be sticky) and
the screening service itself — admission batching (two clients, ONE
shared dispatch per round), the content-addressed result cache (repeat
submission, zero dispatches) and daemon crash/restart resume."""
import threading

import numpy as np
import pytest

from repro.core import stitch
from repro.core.api import BatteryRun, CampaignSpec, PoolSession, RunSpec
from repro.core.faults import FaultPlan, FaultRule
from repro.core.policies import RetryBudgetExhausted, RetryPolicy
from repro.serve import (DONE, FAILED, CacheEntry, ResultCache,
                         SubmissionQueue, admission_key, cell_digest,
                         spec_cells)

SCALE = 0.01
NAN = float("nan")


@pytest.fixture(scope="module")
def session():
    return PoolSession()


def _spec(gen="splitmix64", seed=7, **kw):
    kw.setdefault("scale", SCALE)
    return RunSpec("smallcrush", gen, seeds=(seed,), **kw)


def _spoil_job0(monkeypatch, rounds_to_spoil):
    """Patch ``BatteryRun._dispatch`` so job 0's result is invalid (the
    HELD condition) for the first ``rounds_to_spoil`` dispatches that
    cover it — deterministic kernels never hold naturally."""
    orig = BatteryRun._dispatch
    seen = {"n": 0}

    def flaky(self, row):
        orig(self, row)
        if 0 in {int(j) for j in np.ravel(row)}:
            if seen["n"] < rounds_to_spoil:
                self._results[0][0] = (NAN, NAN)
            seen["n"] += 1

    monkeypatch.setattr(BatteryRun, "_dispatch", flaky)
    return seen


# ------------------------------------------------- hold/release bug sweep

def test_manual_release_does_not_spend_driver_budget(session, monkeypatch):
    """A user-initiated ``release()`` must not reduce the number of
    automatic hold/release retries ``result()`` performs (the retry
    budget regression): the driver budgets against ``driver_retries``,
    while ``retries`` keeps counting every release for reporting."""
    _spoil_job0(monkeypatch, rounds_to_spoil=10**9)     # held forever
    run = session.submit(_spec(retry=RetryPolicy(max_retries=2)))
    while run.pending_rounds:
        run.poll()
    assert run.held() == [0]
    assert run.release() == 1                   # manual — must be FREE
    assert (run.retries, run.driver_retries) == (1, 0)
    with pytest.raises(RetryBudgetExhausted) as ei:
        run.result()                            # job 0 never recovers
    # the driver still got its FULL budget of 2 after the manual release
    assert run.driver_retries == 2
    assert run.retries == 3                     # 1 manual + 2 driver
    assert ei.value.held == [0]


def test_stream_drives_hold_release_rounds(session, monkeypatch):
    """``stream()`` must not exit while jobs are HELD and budget
    remains: a transiently-failing job is released and re-run inside
    the stream, which ends with the run complete."""
    _spoil_job0(monkeypatch, rounds_to_spoil=1)         # fails once
    run = session.submit(_spec(retry=RetryPolicy(max_retries=2)))
    statuses = list(run.stream())
    assert run.done and not run.held()
    assert run.driver_retries == 1              # one retry round, streamed
    assert statuses[-1]["state"] == "done"
    assert run.result().verdict.decision == "PASS"


def test_cancel_is_sticky_after_completion(session):
    """condor_rm of a finished queue is still a rm: ``status()`` must
    report "cancelled" even when every executed job completed."""
    run = session.submit(_spec())
    while run.pending_rounds:
        run.poll()
    assert run.status()["state"] == "done"
    run.cancel()
    assert run.status()["state"] == "cancelled"


# ------------------------------------------------------- cache primitives

def test_cell_digest_sensitivity():
    base = ("smallcrush", SCALE, "splitmix64", 7, 0, 0.01, "reference")
    d = cell_digest(*base)
    assert d == cell_digest(*base)              # deterministic
    for i in range(len(base)):
        other = list(base)
        other[i] = {0: "crush", 1: 0.5, 2: "pcg32", 3: 8, 4: 3,
                    5: 0.05, 6: "accelerated"}[i]
        assert cell_digest(*other) != d, f"field {i} not in the digest"


def test_cache_entry_roundtrip(tmp_path):
    results = {i: (1.0, 0.5) for i in range(10)}
    entry = CacheEntry.from_results(results, 10, alpha=0.01)
    assert entry.complete and entry.decision == stitch.PASS
    path = str(tmp_path / "cell.ck")
    entry.save(path)
    back = CacheEntry.load(path)
    assert back.results == entry.results
    assert (back.decision, back.alpha, back.n_total, back.complete) == \
        (entry.decision, entry.alpha, entry.n_total, entry.complete)
    assert back.verdict().decision == stitch.PASS


def test_partial_entry_serves_only_decided_adaptive_clients():
    decided = CacheEntry.from_results({0: (9.9, 1e-12)}, 10, alpha=0.01)
    assert not decided.complete and decided.decision == stitch.FAIL
    assert decided.serves(stop_on_verdict=True)
    assert not decided.serves(stop_on_verdict=False)
    undecided = CacheEntry.from_results({0: (1.0, 0.5)}, 10, alpha=0.01)
    assert not undecided.serves(True) and not undecided.serves(False)


def test_cache_never_downgrades_complete_entries(tmp_path):
    cache = ResultCache(str(tmp_path / "cache"))
    full = CacheEntry.from_results({i: (1.0, 0.5) for i in range(10)},
                                   10, alpha=0.01)
    partial = CacheEntry.from_results({0: (9.9, 1e-12)}, 10, alpha=0.01)
    cache.put("d", full)
    cache.put("d", partial)                     # must not downgrade
    assert cache.get("d").complete
    # same discipline when the complete entry is only on disk
    cold = ResultCache(str(tmp_path / "cache"))
    cold.put("d", partial)
    assert cold.get("d").complete


def test_demux_positions_inverts_the_merge():
    per_pos = [{0: (1.0, 0.1)}, {0: (2.0, 0.2)}, {0: (3.0, 0.3)}]
    out = stitch.demux_positions(per_pos, {"a": [2, 0], "b": [1]})
    assert out == {"a": [{0: (3.0, 0.3)}, {0: (1.0, 0.1)}],
                   "b": [{0: (2.0, 0.2)}]}


def test_admission_key_groups_compatible_specs():
    a, b = _spec("splitmix64"), _spec("pcg32", seed=99)
    assert admission_key(a) == admission_key(b)     # coalescible
    assert admission_key(a) != admission_key(_spec(alpha=0.05))
    assert admission_key(a) != admission_key(_spec(scale=0.02))
    assert [c.digest for c in spec_cells(a)] != \
        [c.digest for c in spec_cells(b)]


# ------------------------------------------------------- submission queue

def test_two_clients_share_one_dispatch_per_round(tmp_path):
    """The tentpole invariant: two compatible concurrent submissions
    execute as ONE merged batch — one trace, one dispatch per round —
    and each ticket gets exactly its own generator's results back."""
    session = PoolSession()
    queue = SubmissionQueue(session=session,
                            state_dir=str(tmp_path / "state"))
    t1 = queue.submit(_spec("splitmix64"))
    t2 = queue.submit(_spec("pcg32"))
    queue.drain()
    assert queue.batches_formed == 1
    assert t1.batch_id == t2.batch_id == 0
    assert session.total_traces == 1            # ONE merged round program
    r1, r2 = t1.result(), t2.result()
    # shared rounds, not the sum of two solo runs
    assert queue.dispatch_rounds == r1.rounds_run == r2.rounds_run
    assert r1.verdict.decision == r2.verdict.decision == stitch.PASS
    assert "splitmix64" in r1.report and "pcg32" in r2.report
    assert len(r1.results) == len(r2.results) == 10
    assert r1.results != r2.results             # demuxed, not shared


def test_resubmission_served_from_cache_with_zero_dispatches(tmp_path):
    session = PoolSession()
    queue = SubmissionQueue(session=session,
                            state_dir=str(tmp_path / "state"))
    first = queue.submit(_spec())
    queue.drain()
    baseline = queue.dispatch_rounds
    again = queue.submit(_spec())
    assert again.done and again.cache_hits == 1     # done AT submit
    queue.drain()
    assert queue.dispatch_rounds == baseline        # ZERO new dispatches
    assert again.result().results == first.result().results
    assert queue.stats()["cache"]["hits"] >= 1


def test_concurrent_duplicates_dedup_into_one_position(tmp_path):
    queue = SubmissionQueue(session=PoolSession(),
                            state_dir=str(tmp_path / "state"))
    t1, t2 = queue.submit(_spec()), queue.submit(_spec())
    queue.drain()
    assert queue.batches_formed == 1
    assert len(queue.cache) == 1                # one unique cell
    assert t1.result().results == t2.result().results


def test_daemon_restart_resumes_from_checkpoints(tmp_path):
    """Crash recovery: a new daemon on the same state_dir, given the
    same submission, re-forms the same batch and resumes its rounds
    from the checkpoint instead of starting over."""
    state = str(tmp_path / "state")
    q1 = SubmissionQueue(session=PoolSession(), state_dir=state)
    q1.submit(_spec())
    q1.step(flush=True)                         # admit + round 1
    q1.step(flush=True)                         # round 2
    done_before_crash = q1.dispatch_rounds
    assert 0 < done_before_crash                # mid-flight "crash"
    q2 = SubmissionQueue(session=PoolSession(), state_dir=state)
    t = q2.submit(_spec())
    q2.drain()
    res = t.result()
    assert res.verdict.decision == stitch.PASS
    # smallcrush = 10 jobs = 10 rounds on one worker; the restarted
    # daemon only dispatched the rounds the first one hadn't finished
    assert done_before_crash + q2.dispatch_rounds == 10
    assert res.plan_rounds == q2.dispatch_rounds    # residual plan only


def test_max_wait_window_defers_admission(tmp_path):
    queue = SubmissionQueue(session=PoolSession(), max_wait=3600.0,
                            state_dir=str(tmp_path / "state"))
    t = queue.submit(_spec())
    assert queue.step() is False                # window open: no batch
    assert t.state == "queued" and queue.batches_formed == 0
    queue.drain()                               # flush admits regardless
    assert queue.batches_formed == 1 and t.done


def test_queued_ticket_cancel(tmp_path):
    queue = SubmissionQueue(session=PoolSession(), max_wait=3600.0)
    t = queue.submit(_spec())
    assert t.cancel() and t.state == "cancelled"
    assert queue.step() is False                # nothing left to admit
    with pytest.raises(RuntimeError, match="cancelled"):
        t.result()


def test_campaign_ticket_runs_phase_by_phase(tmp_path):
    spec = CampaignSpec("smallcrush", generators=("splitmix64",),
                        n_streams=1, seed=7, waves=(SCALE,),
                        stream_check=False,
                        ledger_path=str(tmp_path / "ledger.ck"))
    queue = SubmissionQueue(session=PoolSession())
    t = queue.submit(spec)
    queue.drain()
    res = t.result()
    assert t.status()["phases_done"] == 1
    assert len(res.survivors) == 1


def test_background_daemon_thread(tmp_path):
    queue = SubmissionQueue(session=PoolSession(),
                            state_dir=str(tmp_path / "state")).start()
    try:
        assert queue.serving
        t1 = queue.submit(_spec("splitmix64"))
        t2 = queue.submit(_spec("pcg32", seed=11))
        r1 = t1.result(timeout=300)
        r2 = t2.result(timeout=300)
        assert r1.verdict.decision == r2.verdict.decision == stitch.PASS
    finally:
        queue.stop()
    assert not queue.serving
    assert threading.active_count() >= 1        # thread joined cleanly


# ---------------------------------------------- fault-domain terminal states

PERSISTENT_CORRUPT = FaultPlan(rules=(FaultRule("corrupt", job=0),))


def test_budget_exhausted_batch_fails_every_ticket(tmp_path):
    """ISSUE 9 satellite: budget exhaustion mid-batch resolves EVERY
    member ticket into the FAILED terminal state with a structured
    failure payload — ``drain()`` returns, nothing hangs, and
    ``result()`` raises instead of returning partial data."""
    queue = SubmissionQueue(session=PoolSession(),
                            state_dir=str(tmp_path / "state"),
                            inject=PERSISTENT_CORRUPT)
    t1 = queue.submit(_spec("splitmix64",
                            retry=RetryPolicy(max_retries=1)))
    t2 = queue.submit(_spec("pcg32", retry=RetryPolicy(max_retries=1)))
    queue.drain()                               # must terminate, not hang
    assert t1.batch_id == t2.batch_id           # one merged batch...
    for t in (t1, t2):
        assert t.state == FAILED and t.done     # ...both tickets resolved
        assert t.failure["held_jobs"] == [0]
        assert "retry budget exhausted" in t.failure["reason"]
        assert t.status()["failure"]["retries"] == 1
        with pytest.raises(RetryBudgetExhausted) as ei:
            t.result()
        assert ei.value.held == [0]


def test_failed_batch_does_not_poison_cache(tmp_path):
    """A failed batch must never serve a poisoned partial: a fresh
    fault-free daemon on the same state dir MISSES the cache for the
    undecided cell and completes it cleanly."""
    state = str(tmp_path / "state")
    q1 = SubmissionQueue(session=PoolSession(), state_dir=state,
                         inject=PERSISTENT_CORRUPT)
    t = q1.submit(_spec(retry=RetryPolicy(max_retries=1)))
    q1.drain()
    assert t.state == FAILED
    q2 = SubmissionQueue(session=PoolSession(), state_dir=state)
    t2 = q2.submit(_spec())
    assert not t2.done                          # no cache hit at submit
    q2.drain()
    assert t2.state == DONE
    res = t2.result()
    assert res.verdict.decision == stitch.PASS
    assert len(res.results) == 10               # complete, job 0 re-run
    assert all(np.isfinite(p) for _s, p in res.results.values())


def test_queue_inject_key_and_stats_health(tmp_path):
    """The fault plan participates in admission compatibility, and
    ``stats()`` reports pool health (ok at launch width)."""
    k_clean = admission_key(_spec())
    k_chaos = admission_key(_spec(inject=PERSISTENT_CORRUPT))
    assert k_clean != k_chaos                   # never merged together
    queue = SubmissionQueue(session=PoolSession(),
                            state_dir=str(tmp_path / "state"))
    queue.submit(_spec())
    queue.drain()
    st = queue.stats()
    assert st["status"] == "ok" and st["workers"] >= 1

"""Shared test config + a minimal ``hypothesis`` fallback.

The test image does not ship ``hypothesis`` and tier-1 must run without
installing new packages. When the real library is importable we use it
unchanged; otherwise we install a tiny deterministic stand-in (fixed
per-test seed, ``max_examples`` drawn examples) into ``sys.modules``
before the test modules import it. Only the strategy surface the suite
actually uses is provided: ``integers``, ``sampled_from``, ``sets``,
``floats``, ``lists``, ``permutations``.
"""
from __future__ import annotations

import sys

try:
    import hypothesis  # noqa: F401  (real library present -> nothing to do)
except ImportError:
    import functools
    import inspect
    import random
    import types
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: seq[r.randrange(len(seq))])

    def floats(min_value, max_value, exclude_min=False,
               exclude_max=False):
        def draw(r):
            lo, hi = float(min_value), float(max_value)
            x = r.uniform(lo, hi)
            if exclude_min and x <= lo:
                x = lo + (hi - lo) * 1e-9
            if exclude_max and x >= hi:
                x = hi - (hi - lo) * 1e-9
            return x
        return _Strategy(draw)

    def lists(elements, min_size=0, max_size=None):
        def draw(r):
            hi = min_size + 10 if max_size is None else max_size
            return [elements.draw(r)
                    for _ in range(r.randint(min_size, hi))]
        return _Strategy(draw)

    def permutations(seq):
        seq = list(seq)
        def draw(r):
            out = list(seq)
            r.shuffle(out)
            return out
        return _Strategy(draw)

    def sets(elements, min_size=0, max_size=None):
        def draw(r):
            hi = min_size + 10 if max_size is None else max_size
            size = r.randint(min_size, hi)
            out, tries = set(), 0
            while len(out) < size and tries < 10000:
                out.add(elements.draw(r))
                tries += 1
            return out
        return _Strategy(draw)

    def settings(max_examples=100, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategy_kw):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples", 20))
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategy_kw.items()}
                    fn(*args, **kwargs, **drawn)
            # hide the drawn parameters from pytest's fixture resolution
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in strategy_kw]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__
            return wrapper
        return deco

    _mod = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.sampled_from = sampled_from
    _st.sets = sets
    _st.floats = floats
    _st.lists = lists
    _st.permutations = permutations
    _mod.given = given
    _mod.settings = settings
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st
